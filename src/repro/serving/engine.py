"""Per-node LLM serving engines.

RealEngine — wraps a JAX model (repro.models.lm.LM): prefill + greedy/top-k
decode with KV-prefix reuse.  Prefix hits restore the cached KV pytree and
feed only the suffix (teacher-forced decode-append), so a request sharing a
10k-token system prompt pays only for its unique tail — the mechanism whose
*group-wide* version the HR-tree provides.

LatencyEngine — a calibrated cost model (prefill/decode tokens-per-second,
continuous-batching slots) for overlay-scale simulations where running a
real model per node would be CPU-prohibitive; calibrated against RealEngine
on the reduced config (see benchmarks/bench_serving_latency.py).
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import cache_slot_read, cache_slot_write
from repro.serving.prefix_cache import PrefixCache


@dataclass
class Request:
    req_id: int
    tokens: list
    max_new: int = 32
    eos_id: int = -1
    session: Optional[str] = None
    arrival: float = 0.0


@dataclass
class Result:
    req_id: int
    output: list
    ttft: float = 0.0
    total: float = 0.0
    cached_tokens: int = 0
    prompt_tokens: int = 0


@dataclass
class PrefillState:
    """Slot-ready request state: a batch-1 cache pytree positioned at
    ``pos`` with the logits of the last prompt token."""
    cache: object
    logits: object      # (1, padded_vocab)
    pos: int
    matched: int        # prefix-cache tokens reused


class RealEngine:
    def __init__(self, cfg, model, params, cache_bytes: int = 1 << 30,
                 max_len: int = 1024):
        self.cfg = cfg
        self.model = model
        self.params = params
        self.max_len = max_len
        self.prefix_cache = PrefixCache(cache_bytes)
        # partial-prefix KV reuse is an attention-cache property: a slot per
        # position, masked by pos.  Recurrent states (mamba/mLSTM/sLSTM)
        # summarize the WHOLE stream and cannot be truncated — those
        # families only reuse on exact full-prefix hits (disabled here).
        self.partial_reuse = all(s.mixer in ("attn", "cross_attn")
                                 for s in cfg.pattern)
        self.batched_traces = 0   # compilations of the slot-pool decode

        def _prefill(params, tokens):
            return model.prefill(params, tokens, max_len=max_len,
                                 block_q=64)

        def _decode(params, cache, tok, pos):
            return model.decode(params, cache, tok, pos)

        def _decode_batched(params, cache, tok, pos, active):
            self.batched_traces += 1   # trace-time side effect only
            return model.decode(params, cache, tok, pos, active=active)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)
        self._decode_batched = jax.jit(_decode_batched)
        self._slot_write = jax.jit(cache_slot_write)
        self._slot_read = jax.jit(cache_slot_read)

    def _cache_nbytes(self, cache) -> int:
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))

    def prefill_request(self, req: Request) -> PrefillState:
        """Prefix-cache match + prefill + teacher-forced suffix replay.

        Shared by the sequential ``generate`` path and slot-pool admission
        (serving/scheduler.py); returns a batch-1 slot-ready state."""
        toks = [int(t) for t in req.tokens]
        matched, entry = self.prefix_cache.match(toks)
        if entry is not None and matched >= 8 and self.partial_reuse:
            cache, pos, suffix = entry.handle, matched, toks[matched:]
        else:
            matched = 0
            boot = max(1, min(len(toks), 8))
            _, cache = self._prefill(
                self.params, jnp.asarray([toks[:boot]], jnp.int32))
            pos, suffix = boot, toks[boot:]
        # teacher-forced decode-append over the (uncached) suffix
        logits = None
        for t in suffix:
            logits, cache = self._decode(
                self.params, cache, jnp.asarray([[t]], jnp.int32),
                jnp.asarray([pos], jnp.int32))
            pos += 1
        if logits is None:  # full prefix hit: replay last token for logits
            logits, cache = self._decode(
                self.params, cache, jnp.asarray([[toks[-1]]], jnp.int32),
                jnp.asarray([pos - 1], jnp.int32))
        return PrefillState(cache, logits, pos, matched)

    def generate(self, req: Request, now: float = 0.0) -> Result:
        """One-slot sequential decode (thin wrapper over prefill_request)."""
        t0 = time.monotonic()
        st = self.prefill_request(req)
        cache, logits, pos = st.cache, st.logits, st.pos
        ttft = time.monotonic() - t0
        out = []
        for _ in range(req.max_new):
            nxt = int(jnp.argmax(logits[0]))
            out.append(nxt)
            if nxt == req.eos_id or pos >= self.max_len - 1:
                break
            logits, cache = self._decode(
                self.params, cache, jnp.asarray([[nxt]], jnp.int32),
                jnp.asarray([pos], jnp.int32))
            pos += 1
        # insert only the KV-covered prefix: after an eos/len break the last
        # appended token was never decoded, so its position holds no KV —
        # pos counts exactly the tokens whose state is in the cache
        full = ([int(t) for t in req.tokens] + out)[:pos]
        self.prefix_cache.insert(full, cache, self._cache_nbytes(cache))
        return Result(req.req_id, out, ttft=ttft,
                      total=time.monotonic() - t0,
                      cached_tokens=st.matched,
                      prompt_tokens=len(req.tokens))


@dataclass
class LatencyEngineConfig:
    prefill_tps: float = 8_000.0     # prompt tokens/s (single request)
    decode_tps: float = 60.0         # generated tokens/s per request
    batch_slots: int = 8             # continuous-batching concurrency
    overhead_s: float = 0.02
    hw_score: float = 5.0            # the paper's 1..10 capacity score


class LatencyEngine:
    """Deterministic continuous-batching cost model on the simnet clock.

    ``submit`` returns (ttft, completion_time_offset, cached_tokens) given
    the current queue state; slot release is the caller's responsibility
    via the returned completion offset (model_node schedules it)."""

    def __init__(self, ecfg: LatencyEngineConfig,
                 cache_bytes: int = 1 << 28):
        self.ecfg = ecfg
        self.prefix_cache = PrefixCache(cache_bytes)
        self.busy: list[float] = []       # completion times of active slots
        self.active = 0

    def service_times(self, n_prompt: int, n_cached: int, n_out: int,
                      now: float) -> tuple[float, float]:
        e = self.ecfg
        scale = e.hw_score / 5.0
        # slot admission: wait for a free slot if all are busy
        self.busy = [t for t in self.busy if t > now]
        if len(self.busy) >= e.batch_slots:
            start = sorted(self.busy)[len(self.busy) - e.batch_slots]
        else:
            start = now
        # batching interference: decode tps degrades with occupancy
        occupancy = min(len(self.busy) + 1, e.batch_slots)
        interference = 1.0 + 0.15 * (occupancy - 1)
        t_prefill = (n_prompt - n_cached) / (e.prefill_tps * scale)
        t_decode = n_out * interference / (e.decode_tps * scale)
        ttft = (start - now) + e.overhead_s + t_prefill
        total = ttft + t_decode
        self.busy.append(now + total)
        return ttft, total
