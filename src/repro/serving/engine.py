"""Per-node LLM serving engines.

RealEngine — wraps a JAX model (repro.models.lm.LM): prefill + greedy/top-k
decode with KV-prefix reuse.  Pure-attention families serve from a **paged
KV pool**: a node-wide per-layer page arena (models/lm.py
``paged_arena_zeros``) plus per-request page tables, so a prefix-cache hit
*aliases* the holder's pages with a refcount bump (serving/page_pool)
instead of copying a cache pytree — admission is O(suffix), not O(cache
bytes), and KV memory scales with live tokens.  Recurrent families
(mamba/xLSTM) fall back to the dense batch-1 cache path.  This is the
node-local mechanism whose *group-wide* version the HR-tree provides.

LatencyEngine — a calibrated cost model (prefill/decode tokens-per-second,
continuous-batching slots) for overlay-scale simulations where running a
real model per node would be CPU-prohibitive; calibrated against RealEngine
on the reduced config (see benchmarks/bench_serving_latency.py).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import (arena_gather_pages, arena_scatter_pages,
                             cache_slot_read, cache_slot_write)
from repro.serving.page_pool import OutOfPages, PageAllocator, PagedHandle
from repro.serving.prefix_cache import BLOCK, PrefixCache
from repro.training.compression import (compress_kv_blocks,
                                        decompress_kv_blocks)


@dataclass
class Request:
    req_id: int
    tokens: list
    max_new: int = 32
    eos_id: int = -1
    session: Optional[str] = None
    arrival: float = 0.0


@dataclass
class Result:
    req_id: int
    output: list
    ttft: float = 0.0
    total: float = 0.0
    cached_tokens: int = 0
    prompt_tokens: int = 0


class NgramDrafter:
    """Self-speculative prompt-lookup drafter (no draft model).

    Indexes every n-gram (n <= ``max_n``) of a request's prompt plus its
    committed generation, mapping it to the position right after its most
    recent occurrence *that has a continuation*.  ``draft(k)`` matches the
    longest indexed suffix of the context and proposes the k tokens that
    followed it last time — fully deterministic, so speculative decode
    stays token-identical to greedy decoding (drafts are only accepted
    when they equal the model's own argmax) and CI can gate the accept
    counters.  Repetitive streams (templates, code, loops — including the
    model's own greedy cycles) draft well; novel text drafts nothing and
    the verify window degenerates to a normal one-token decode."""

    __slots__ = ("tokens", "index", "max_n")

    def __init__(self, tokens, max_n: int = 3):
        self.tokens: list = []
        self.index: dict = {}
        self.max_n = max_n
        self.extend(tokens)

    def extend(self, toks):
        """Append committed tokens, indexing n-grams as they gain a
        continuation (an n-gram ending at the stream head has nothing to
        propose yet, so it is indexed when the next token arrives)."""
        for t in toks:
            pos = len(self.tokens)
            for n in range(1, self.max_n + 1):
                if pos >= n:
                    self.index[tuple(self.tokens[pos - n:pos])] = pos
            self.tokens.append(int(t))

    def draft(self, k: int) -> list:
        """Up to ``k`` proposed continuation tokens (possibly fewer when
        the match sits near the stream head; empty on no match)."""
        if k <= 0:
            return []
        for n in range(min(self.max_n, len(self.tokens)), 0, -1):
            cont = self.index.get(tuple(self.tokens[-n:]))
            if cont is not None:
                return self.tokens[cont:cont + k]
        return []


@dataclass
class PrefillState:
    """Slot-ready request state positioned at ``pos`` with the logits of
    the last prompt token.  Dense engines carry a batch-1 cache pytree in
    ``cache``; paged engines carry the request's physical page list in
    ``pages`` (the KV itself lives in the engine's shared arena)."""
    cache: object
    logits: object      # (1, padded_vocab)
    pos: int
    matched: int        # prefix-cache tokens reused
    pages: Optional[list] = None


class RealEngine:
    def __init__(self, cfg, model, params, cache_bytes: int = 1 << 30,
                 max_len: int = 1024, paged: Optional[bool] = None,
                 num_pages: Optional[int] = None):
        self.cfg = cfg
        self.model = model
        self.params = params
        self.max_len = max_len
        self.prefix_cache = PrefixCache(cache_bytes)
        # partial-prefix KV reuse is an attention-cache property: a slot per
        # position, masked by pos.  Recurrent states (mamba/mLSTM/sLSTM)
        # summarize the WHOLE stream and cannot be truncated — those
        # families only reuse on exact full-prefix hits (disabled here).
        self.partial_reuse = all(s.mixer in ("attn", "cross_attn")
                                 for s in cfg.pattern)
        self.batched_traces = 0   # compilations of the slot-pool decode
        self.batched_prefill_traces = 0   # compilations of batched admission
        self.prefill_dispatches = 0       # jitted prefill_paged calls issued
        self.prefill_tokens = 0           # real (non-pad) tokens prefilled
        # speculative decode counters (scheduler-driven verify rounds)
        self.spec_traces = 0      # compilations of the batched verify
        self.spec_dispatches = 0  # verify_paged dispatches issued
        self.spec_tokens = 0      # tokens committed by verify rounds
        self.spec_drafted = 0     # draft tokens proposed
        self.spec_accepted = 0    # draft tokens accepted (== model argmax)
        self.spec_draftless_rounds = 0  # rounds served by the one-token
                                        # pool decode (no slot drafted)
        # cross-node KV page migration counters (overlay Replicator)
        self.kv_exported_pages = 0   # pages shipped to fetching peers
        self.kv_imported_pages = 0   # pages scattered in from peers
        self.kv_export_events = 0
        self.kv_import_events = 0
        # wire codec for exported pages (training/compression.py):
        # "fp16" | "int8" | "raw".  fp16 halves f32 arenas; 16-bit arenas
        # (bf16) ship raw — same bytes, and a bf16 -> fp16 cast would
        # overflow |v| > 65504 to inf for zero wire savings
        self.kv_wire_mode = ("fp16" if cfg.compute_dtype.itemsize == 4
                             else "raw")
        # paged KV pool: pure-attention families only (recurrent mixers
        # have O(1) state — nothing to page)
        self.paged = (model.supports_paging() if paged is None
                      else bool(paged) and model.supports_paging())
        # speculative decode needs per-position KV to roll back by position
        # — paged pool only; dense/recurrent engines fall back to one
        # token per round
        self.spec = bool(self.paged and cfg.spec_enabled and cfg.spec_k > 0)
        self.block = BLOCK
        if self.paged:
            self.max_pages = -(-max_len // BLOCK)     # table width (ceil)
            # page 0 is scratch; default arena fits ~16 max_len streams —
            # under pressure the prefix cache is evicted page-by-page
            self.num_pages = num_pages or (1 + 16 * self.max_pages)
            self.allocator = PageAllocator(self.num_pages)
            self.arena = model.paged_arena_zeros(self.num_pages, BLOCK)
            self.page_bytes = sum(
                x.shape[0] * BLOCK * x.shape[3] * x.shape[4]
                * x.dtype.itemsize for x in jax.tree.leaves(self.arena))
            self.prefix_cache.on_release = \
                lambda h: self.allocator.decref(h.pages)

        def _prefill(params, tokens):
            return model.prefill(params, tokens, max_len=max_len,
                                 block_q=64)

        def _decode(params, cache, tok, pos):
            return model.decode(params, cache, tok, pos)

        def _decode_batched(params, cache, tok, pos, active):
            self.batched_traces += 1   # trace-time side effect only
            return model.decode(params, cache, tok, pos, active=active)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)
        self._decode_batched = jax.jit(_decode_batched)
        self._slot_write = jax.jit(cache_slot_write)
        self._slot_read = jax.jit(cache_slot_read)
        if self.paged:
            # donate the arena so scatters update it in place where the
            # backend supports donation (CPU silently copies)
            donate = () if jax.default_backend() == "cpu" else (1,)

            def _prefill_paged(params, arena, pt, tok, pos0):
                return model.prefill_paged(params, arena, pt, tok, pos0)

            def _decode_paged(params, arena, pt, tok, pos):
                return model.decode_paged(params, arena, pt, tok, pos)

            def _query_paged(params, arena, pt, tok, pos):
                logits, _ = model.decode_paged(params, arena, pt, tok, pos,
                                               write=False)
                return logits

            def _decode_paged_batched(params, arena, pt, tok, pos, active):
                self.batched_traces += 1   # trace-time side effect only
                return model.decode_paged(params, arena, pt, tok, pos,
                                          active=active)

            def _prefill_paged_batched(params, arena, pt, tok, pos0,
                                       active):
                self.batched_prefill_traces += 1   # trace-time only
                return model.prefill_paged(params, arena, pt, tok, pos0,
                                           active=active)

            def _verify_paged_batched(params, arena, pt, tok, pos, n_tok):
                self.spec_traces += 1   # trace-time side effect only
                return model.verify_paged(params, arena, pt, tok, pos,
                                          n_tok=n_tok)

            self._prefill_paged = jax.jit(_prefill_paged,
                                          donate_argnums=donate)
            self._verify_paged_batched = jax.jit(_verify_paged_batched,
                                                 donate_argnums=donate)
            self._prefill_paged_batched = jax.jit(_prefill_paged_batched,
                                                  donate_argnums=donate)
            self._decode_paged = jax.jit(_decode_paged,
                                         donate_argnums=donate)
            self._query_paged = jax.jit(_query_paged)
            # page-import scatter: donate the arena so landing replicated
            # pages updates in place instead of copying every layer's
            # whole arena per import (arena is arg 0 here, not arg 1)
            self._scatter_pages = jax.jit(
                arena_scatter_pages,
                donate_argnums=() if not donate else (0,))
            # same attribute as the dense pool decode on purpose: the
            # scheduler (and dispatch-count tests) treat "the one batched
            # decode" uniformly across modes
            self._decode_batched = jax.jit(_decode_paged_batched,
                                           donate_argnums=donate)

    def _cache_nbytes(self, cache) -> int:
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))

    @property
    def spec_accept_rate(self) -> float:
        """Fraction of proposed draft tokens the model accepted (0 until
        the first draft) — broadcast by ModelNode alongside kv_pressure."""
        return (self.spec_accepted / self.spec_drafted
                if self.spec_drafted else 0.0)

    # ------------------------------------------------------------------
    # paged-pool page management (host side)
    # ------------------------------------------------------------------
    def alloc_pages(self, n: int = 1) -> list:
        """Allocate ``n`` pages, evicting LRU prefix-cache entries under
        pressure (their pages free once no live request aliases them)."""
        while True:
            try:
                return self.allocator.alloc(n)
            except OutOfPages:
                if not self.prefix_cache.pop_lru():
                    raise

    def release_pages(self, pages):
        self.allocator.decref(pages)

    def ensure_page_for(self, pages: list, pos: int):
        """Grow ``pages`` so the block holding position ``pos`` exists
        (called before every decode write that may cross into a new
        block)."""
        while len(pages) <= pos // self.block:
            pages.extend(self.alloc_pages(1))

    def page_table_row(self, pages) -> np.ndarray:
        """(1, max_pages) int32 page-table row; unallocated logical blocks
        point at the scratch page 0 and are masked by position."""
        row = np.zeros((1, self.max_pages), np.int32)
        row[0, :len(pages)] = pages
        return row

    def insert_prefix(self, full_tokens, pages):
        """Zero-copy prefix-cache insert: the entry holds page ids (one
        extra reference each), never KV bytes."""
        n_cov = len(full_tokens) // self.block
        if not n_cov:
            return
        covered = list(pages[:n_cov])
        self.allocator.incref(covered)
        handle = PagedHandle(tuple(covered), n_cov * self.block)
        self.prefix_cache.insert(full_tokens, handle,
                                 n_cov * self.page_bytes)

    def live_kv_bytes(self) -> int:
        """Physical KV footprint: pages in use x bytes per page (aliased
        pages counted once — the point of the paged pool)."""
        if not self.paged:
            return self.prefix_cache.used_bytes
        return self.allocator.used_count * self.page_bytes

    # ------------------------------------------------------------------
    # cross-node page migration (overlay kv_fetch / kv_pages)
    # ------------------------------------------------------------------
    def export_pages(self, handle: PagedHandle, depth: Optional[int] = None,
                     mode: Optional[str] = None) -> dict:
        """Gather the first ``depth`` pages of a prefix entry out of the
        per-layer arenas into a host-side wire buffer.

        Read-only: aliased pages are never mutated and no refcounts move
        — the holder keeps serving from (and may later evict) the same
        physical pages while a copy ships.  ``mode`` picks the wire codec
        (``kv_wire_mode`` default); the buffer is a pure dict of bytes /
        ints so the overlay can msgpack + chunk it."""
        assert self.paged, "page export requires the paged pool"
        if depth is not None:
            handle = handle.prefix(depth, self.block)
        pages = list(handle.pages)
        assert pages, "empty page export"
        mode = mode or self.kv_wire_mode
        gathered = arena_gather_pages(self.arena, pages)
        layers = [{n: compress_kv_blocks(layer[n], mode) for n in ("k", "v")}
                  for layer in gathered]
        self.kv_exported_pages += len(pages)
        self.kv_export_events += 1
        return {"n_pages": len(pages), "mode": mode, "layers": layers}

    def import_pages(self, buf: dict, chains: list) -> PagedHandle:
        """Allocate local pages, scatter a peer's exported K/V blocks into
        the arenas, and register the prefix in ``PrefixCache`` under its
        BLOCK-chain digests — the next admission aliases it exactly as if
        this node had prefilled it (zero prefill dispatches for the
        replicated blocks).

        ``chains`` is the request's digest chain covering the buffer
        (``chains[i]`` keys blocks 0..i).  Raises ``OutOfPages`` when the
        arena cannot host the pages even after LRU eviction; any pages
        allocated before the failure are released — a failed import
        leaves allocator and arena exactly as they were, and the caller
        falls back to plain prefill."""
        assert self.paged, "page import requires the paged pool"
        n = int(buf["n_pages"])
        chains = list(chains)[:n]
        if n < 1 or len(chains) < n:
            raise ValueError(f"import of {n} pages with {len(chains)} "
                             f"chain digests")
        pages = self.alloc_pages(n)
        try:
            dtype = self.cfg.compute_dtype
            blocks = tuple(
                {name: decompress_kv_blocks(layer[name], dtype)
                 for name in ("k", "v")}
                for layer in buf["layers"])
            self.arena = self._scatter_pages(
                self.arena, jnp.asarray(pages, jnp.int32), blocks)
        except BaseException:
            self.allocator.decref(pages)     # released, never registered
            raise
        handle = PagedHandle(tuple(pages), n * self.block)
        # the pages' initial reference becomes the cache entry's (its
        # on_release decref balances the alloc above)
        self.prefix_cache.insert_chains(chains, handle,
                                        n * self.page_bytes)
        self.kv_imported_pages += n
        self.kv_import_events += 1
        return handle

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def prefill_request(self, req: Request) -> PrefillState:
        """Prefix-cache match + prefill of the uncached suffix.

        Shared by the sequential ``generate`` path and slot-pool admission
        (serving/scheduler.py); returns a batch-1 slot-ready state.  Paged
        engines alias a hit's pages (refcount bump, no KV copy) and run
        the suffix through the chunked paged prefill; dense engines keep
        the PR-1 boot-prefill + teacher-forced decode-append replay."""
        if self.paged:
            return self._prefill_request_paged(req)
        toks = [int(t) for t in req.tokens]
        matched, entry = self.prefix_cache.match(toks)
        if entry is not None and matched >= 8 and self.partial_reuse:
            cache, pos, suffix = entry.handle, matched, toks[matched:]
        else:
            matched = 0
            boot = max(1, min(len(toks), 8))
            _, cache = self._prefill(
                self.params, jnp.asarray([toks[:boot]], jnp.int32))
            pos, suffix = boot, toks[boot:]
        # teacher-forced decode-append over the (uncached) suffix
        logits = None
        for t in suffix:
            logits, cache = self._decode(
                self.params, cache, jnp.asarray([[t]], jnp.int32),
                jnp.asarray([pos], jnp.int32))
            pos += 1
        if logits is None:  # full prefix hit: replay last token for logits
            logits, cache = self._decode(
                self.params, cache, jnp.asarray([[toks[-1]]], jnp.int32),
                jnp.asarray([pos - 1], jnp.int32))
        return PrefillState(cache, logits, pos, matched)

    def _match_and_alias(self, toks: list) -> tuple[int, list]:
        """Prefix-cache match + zero-copy alias of the hit's pages.

        Returns (matched, pages): ``matched`` block-aligned tokens whose
        KV the request reuses by reference (refcount bump — zero KV bytes
        move), ``pages`` the aliased physical pages."""
        matched, entry = self.prefix_cache.match(toks)
        if (entry is not None and isinstance(entry.handle, PagedHandle)
                and matched >= self.block):
            shared = list(entry.handle.pages[:matched // self.block])
            self.allocator.incref(shared)        # zero-copy alias
            return matched, shared
        return 0, []

    def _prefill_request_paged(self, req: Request) -> PrefillState:
        """Paged admission: alias cached pages, chunk-prefill the suffix.

        A hit contributes its pages by reference (refcount bump — zero KV
        bytes move); the uncached suffix is processed in BLOCK-token
        teacher-forced chunks, each ONE dispatch that scatters the chunk's
        K/V into a fresh page and attends over the whole page table —
        admission cost is O(suffix), never O(cached prefix)."""
        toks = [int(t) for t in req.tokens]
        matched, pages = self._match_and_alias(toks)
        pos = matched
        logits_last = None
        try:
            pos, logits_last = self._prefill_chunks(toks, pages, pos)
        except BaseException:
            if pages:                # release aliased + fresh references
                self.allocator.decref(pages)
            raise
        if logits_last is None:
            logits_last = self._replay_last_token(toks, pages, pos)
        return PrefillState(None, logits_last, pos, matched, pages=pages)

    def _replay_last_token(self, toks, pages, pos):
        """Block-aligned prompt fully cached: query-only replay of the
        last token — aliased pages are never written."""
        pt = jnp.asarray(self.page_table_row(pages))
        return self._query_paged(
            self.params, self.arena, pt,
            jnp.asarray([[toks[-1]]], jnp.int32),
            jnp.asarray([pos - 1], jnp.int32))

    def _prefill_chunks(self, toks, pages, pos):
        blk = self.block
        logits_last = None
        while pos < len(toks):
            pages.extend(self.alloc_pages(1))
            # pad tail of the last partial chunk: pad logits are ignored
            # and pad K/V is overwritten by later decode writes before any
            # position mask exposes it
            chunk = toks[pos:pos + blk]
            buf = chunk + [0] * (blk - len(chunk))
            pt = jnp.asarray(self.page_table_row(pages))
            logits, self.arena = self._prefill_paged(
                self.params, self.arena, pt,
                jnp.asarray([buf], jnp.int32), jnp.asarray([pos], jnp.int32))
            self.prefill_dispatches += 1
            self.prefill_tokens += len(chunk)
            logits_last = logits[:, len(chunk) - 1]
            pos += len(chunk)
        return pos, logits_last

    # ------------------------------------------------------------------
    # batched admission (paged): one dispatch stream for a whole round
    # ------------------------------------------------------------------
    def prefill_requests(self, reqs: list, batch: Optional[int] = None
                         ) -> list:
        """Batched paged admission: every request's divergence suffix
        marches through ONE shared BLOCK-chunk grid.

        Per chunk step there is a single ``prefill_paged`` dispatch over a
        fixed ``batch``-row grid (per-row page tables, per-row block-
        aligned start positions, masked tail rows for suffixes that ended
        early), so K co-routed siblings cost ``max(chunks)`` dispatches
        instead of ``sum(chunks)`` — the per-request admission loop the
        sequential path still pays.  Prefix hits alias cached pages first
        exactly like ``prefill_request``; rows whose prompt is fully
        cached skip the grid and replay their last token query-only.

        Returns one ``PrefillState`` per request, in input order."""
        assert self.paged, "batched admission requires the paged pool"
        if not reqs:
            return []
        B = batch or len(reqs)
        assert len(reqs) <= B
        blk = self.block
        rows = []
        try:
            for req in reqs:
                toks = [int(t) for t in req.tokens]
                matched, pages = self._match_and_alias(toks)
                rows.append({"toks": toks, "pages": pages, "pos": matched,
                             "matched": matched, "logits": None})
            n_steps = max((len(r["toks"]) - r["pos"] + blk - 1) // blk
                          for r in rows)
            for _ in range(n_steps):
                tok = np.zeros((B, blk), np.int32)
                pos0 = np.zeros((B,), np.int32)
                act = np.zeros((B,), bool)
                ptab = np.zeros((B, self.max_pages), np.int32)
                ends = []                    # rows finishing this step
                for i, r in enumerate(rows):
                    if r["pos"] >= len(r["toks"]):
                        continue             # suffix done: masked this step
                    r["pages"].extend(self.alloc_pages(1))
                    chunk = r["toks"][r["pos"]:r["pos"] + blk]
                    tok[i, :len(chunk)] = chunk
                    pos0[i] = r["pos"]
                    act[i] = True
                    ptab[i, :len(r["pages"])] = r["pages"]
                    if r["pos"] + len(chunk) >= len(r["toks"]):
                        ends.append((i, len(chunk)))
                    r["pos"] += len(chunk)
                    self.prefill_tokens += len(chunk)
                logits, self.arena = self._prefill_paged_batched(
                    self.params, self.arena, jnp.asarray(ptab),
                    jnp.asarray(tok), jnp.asarray(pos0), jnp.asarray(act))
                self.prefill_dispatches += 1
                for i, off in ends:
                    rows[i]["logits"] = logits[i:i + 1, off - 1]
        except BaseException:
            for r in rows:           # release aliased + fresh references
                if r["pages"]:
                    self.allocator.decref(r["pages"])
            raise
        out = []
        for r in rows:
            if r["logits"] is None:  # full block-aligned hit
                r["logits"] = self._replay_last_token(
                    r["toks"], r["pages"], r["pos"])
            out.append(PrefillState(None, r["logits"], r["pos"],
                                    r["matched"], pages=r["pages"]))
        return out

    # ------------------------------------------------------------------
    # sequential generation
    # ------------------------------------------------------------------
    def generate(self, req: Request, now: float = 0.0) -> Result:
        """One-slot sequential decode (thin wrapper over prefill_request)."""
        if self.paged:
            return self._generate_paged(req)
        t0 = time.monotonic()
        st = self.prefill_request(req)
        cache, logits, pos = st.cache, st.logits, st.pos
        ttft = time.monotonic() - t0
        out = []
        for _ in range(req.max_new):
            nxt = int(jnp.argmax(logits[0]))
            out.append(nxt)
            if nxt == req.eos_id or pos >= self.max_len - 1:
                break
            logits, cache = self._decode(
                self.params, cache, jnp.asarray([[nxt]], jnp.int32),
                jnp.asarray([pos], jnp.int32))
            pos += 1
        # insert only the KV-covered prefix: after an eos/len break the last
        # appended token was never decoded, so its position holds no KV —
        # pos counts exactly the tokens whose state is in the cache
        full = ([int(t) for t in req.tokens] + out)[:pos]
        self.prefix_cache.insert(full, cache, self._cache_nbytes(cache))
        return Result(req.req_id, out, ttft=ttft,
                      total=time.monotonic() - t0,
                      cached_tokens=st.matched,
                      prompt_tokens=len(req.tokens))

    def _generate_paged(self, req: Request) -> Result:
        t0 = time.monotonic()
        st = self.prefill_request(req)
        pages, logits, pos = st.pages, st.logits, st.pos
        ttft = time.monotonic() - t0
        out = []
        try:
            for _ in range(req.max_new):
                nxt = int(jnp.argmax(logits[0]))
                out.append(nxt)
                if nxt == req.eos_id or pos >= self.max_len - 1:
                    break
                self.ensure_page_for(pages, pos)
                logits, self.arena = self._decode_paged(
                    self.params, self.arena,
                    jnp.asarray(self.page_table_row(pages)),
                    jnp.asarray([[nxt]], jnp.int32),
                    jnp.asarray([pos], jnp.int32))
                pos += 1
            full = ([int(t) for t in req.tokens] + out)[:pos]
            self.insert_prefix(full, pages)  # zero-copy (page refs)
        finally:
            self.release_pages(pages)        # request's own reference
        return Result(req.req_id, out, ttft=ttft,
                      total=time.monotonic() - t0,
                      cached_tokens=st.matched,
                      prompt_tokens=len(req.tokens))


@dataclass
class LatencyEngineConfig:
    prefill_tps: float = 8_000.0     # prompt tokens/s (single request)
    decode_tps: float = 60.0         # generated tokens/s per request
    batch_slots: int = 8             # continuous-batching concurrency
    overhead_s: float = 0.02
    hw_score: float = 5.0            # the paper's 1..10 capacity score


class LatencyEngine:
    """Deterministic continuous-batching cost model on the simnet clock.

    ``submit`` returns (ttft, completion_time_offset, cached_tokens) given
    the current queue state; slot release is the caller's responsibility
    via the returned completion offset (model_node schedules it)."""

    def __init__(self, ecfg: LatencyEngineConfig,
                 cache_bytes: int = 1 << 28):
        self.ecfg = ecfg
        self.prefix_cache = PrefixCache(cache_bytes)
        self.busy: list[float] = []       # completion times of active slots

    def service_times(self, n_prompt: int, n_cached: int, n_out: int,
                      now: float) -> tuple[float, float]:
        e = self.ecfg
        scale = e.hw_score / 5.0
        # slot admission: wait for a free slot if all are busy
        self.busy = [t for t in self.busy if t > now]
        if len(self.busy) >= e.batch_slots:
            start = sorted(self.busy)[len(self.busy) - e.batch_slots]
        else:
            start = now
        # batching interference: decode tps degrades with occupancy
        occupancy = min(len(self.busy) + 1, e.batch_slots)
        interference = 1.0 + 0.15 * (occupancy - 1)
        t_prefill = (n_prompt - n_cached) / (e.prefill_tps * scale)
        t_decode = n_out * interference / (e.decode_tps * scale)
        ttft = (start - now) + e.overhead_s + t_prefill
        total = ttft + t_decode
        self.busy.append(now + total)
        return ttft, total
