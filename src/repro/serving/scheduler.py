"""Slot-pool continuous-batching scheduler for the real engine.

Every ``step()`` issues ONE jitted batched decode dispatch for the whole
pool — dead rows are masked, not recompiled — and token selection / EOS
handling is vectorized over the batch.

With a **paged engine** (serving/engine.py, pure-attention families) the
pool is a host-side ``(max_active, max_pages)`` page-table array over the
engine's node-wide KV arena: admission installs the request's page list
into a free row (a prefix-cache hit arrives as *aliased* pages — zero KV
bytes copied), a fresh page is allocated only when a row's position
crosses a block boundary, and completion registers the row's pages with
``PrefixCache`` by reference and drops the request's refcount.  Paged
admission is itself **batched**: every round drains the queue into all
free slots through one shared-grid ``prefill_paged`` dispatch stream
(engine.prefill_requests) — K admitted requests cost max(chunks)
dispatches, not K chunk loops.  Dense engines (recurrent mixers) keep
the PR-1 ``(R, max_active, ...)`` cache pool with scatter-on-admit /
gather-on-finish and per-request admission.

Admission keeps session stickiness semantics and a longest-prefix-match
preference (the node-local analogue of the HR-tree's group-level cache
affinity).  The match length is probed read-only via ``PrefixCache.peek``
ONCE at submit time and carried with the queued request — the admission
scan ranks on the cached hint instead of re-hashing every queued prompt on
every admission.
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.serving.engine import NgramDrafter, RealEngine, Request, Result


@dataclass
class _Slot:
    req: Request
    pos: int
    out: list = field(default_factory=list)
    t_start: float = 0.0
    ttft: float = 0.0
    cached_tokens: int = 0
    pages: list = field(default_factory=list)   # paged engines only
    drafter: object = None                      # NgramDrafter (spec mode)


@dataclass
class _Queued:
    req: Request
    hint: int           # block-aligned prefix-cache match length at submit


class Scheduler:
    def __init__(self, engine: RealEngine, max_active: int = 4,
                 prefer_cache_hits: bool = True):
        self.engine = engine
        self.max_active = max_active
        self.prefer_cache_hits = prefer_cache_hits
        self.queue: collections.deque = collections.deque()
        self.slots: list[Optional[_Slot]] = [None] * max_active
        self.done: list[Result] = []
        self.metrics = {"admitted": 0, "completed": 0, "queue_peak": 0,
                        "decode_calls": 0, "rounds": 0}
        # speculative n-gram decode: one multi-token verify dispatch per
        # round instead of the one-token pool decode (paged engines only;
        # cfg.spec_enabled/spec_k are serving policy, not arch traits)
        self.spec = engine.spec
        self._spec_w = engine.cfg.spec_k + 1 if self.spec else 1
        self._logits = jnp.zeros((max_active, engine.cfg.padded_vocab),
                                 jnp.float32)
        if engine.paged:
            # page-table pool: rows of physical page ids into the engine's
            # shared arena; 0 = scratch page (inactive / unallocated)
            self._cache = None
            self._ptab = np.zeros((max_active, engine.max_pages), np.int32)
        else:
            # dense pool: one batched cache pytree allocated once for the
            # engine's max_len
            self._cache = engine.model.cache_zeros(max_active,
                                                   engine.max_len)
            self._ptab = None

    @property
    def active(self) -> list:
        return [s for s in self.slots if s is not None]

    def submit(self, req: Request):
        hint = 0
        if self.prefer_cache_hits:
            hint, _ = self.engine.prefix_cache.peek(
                [int(t) for t in req.tokens])
        self.queue.append(_Queued(req, hint))
        self.metrics["queue_peak"] = max(self.metrics["queue_peak"],
                                         len(self.queue))

    # ------------------------------------------------------------------
    def _pick_request(self) -> Request:
        ix = 0
        if self.prefer_cache_hits and len(self.queue) > 1:
            best, best_len = 0, -1
            for i, q in enumerate(self.queue):
                if q.hint > best_len:
                    best, best_len = i, q.hint
            ix = best
        q = self.queue[ix]
        del self.queue[ix]
        return q.req

    def _admit_one(self):
        free = next((i for i, s in enumerate(self.slots) if s is None), None)
        if free is None or not self.queue:
            return
        req = self._pick_request()
        t0 = time.monotonic()
        eng = self.engine
        st = eng.prefill_request(req)
        if eng.paged:
            # zero-copy admission: the slot row IS the page table — shared
            # prefix pages alias the cache holder's pages (refcounted)
            self._ptab[free, :] = 0
            self._ptab[free, :len(st.pages)] = st.pages
        else:
            self._cache = eng._slot_write(self._cache, st.cache, free)
        self._logits = self._logits.at[free].set(st.logits[0])
        self.slots[free] = _Slot(req, st.pos, t_start=t0,
                                 ttft=time.monotonic() - t0,
                                 cached_tokens=st.matched,
                                 pages=st.pages or [],
                                 drafter=self._new_drafter(req))
        self.metrics["admitted"] += 1

    def _admit_batch(self):
        """Paged admission for a whole round: drain the queue into every
        free slot through ONE batched ``prefill_paged`` dispatch stream
        (engine.prefill_requests) — K admitted requests cost max(chunks)
        dispatches on a shared grid instead of K separate chunk loops."""
        free = [i for i, s in enumerate(self.slots) if s is None]
        picked = []
        for slot in free:
            if not self.queue:
                break
            picked.append((slot, self._pick_request()))
        if not picked:
            return
        t0 = time.monotonic()
        states = self.engine.prefill_requests(
            [req for _, req in picked], batch=self.max_active)
        ttft = time.monotonic() - t0
        for (slot, req), st in zip(picked, states):
            self._ptab[slot, :] = 0
            self._ptab[slot, :len(st.pages)] = st.pages
            self._logits = self._logits.at[slot].set(st.logits[0])
            self.slots[slot] = _Slot(req, st.pos, t_start=t0, ttft=ttft,
                                     cached_tokens=st.matched,
                                     pages=st.pages or [],
                                     drafter=self._new_drafter(req))
            self.metrics["admitted"] += 1

    def _new_drafter(self, req: Request):
        return NgramDrafter(req.tokens) if self.spec else None

    # ------------------------------------------------------------------
    def step(self):
        """One continuous-batching round: admit into free slots, then ONE
        batched decode dispatch for every still-active slot."""
        if self.engine.paged:
            self._admit_batch()
        else:
            while self.queue and any(s is None for s in self.slots):
                self._admit_one()
        active_ix = [i for i, s in enumerate(self.slots) if s is not None]
        if not active_ix:
            return
        self.metrics["rounds"] += 1
        nxt = np.asarray(jnp.argmax(self._logits, axis=-1))
        finished, cont = [], []
        for i in active_ix:
            s = self.slots[i]
            tok = int(nxt[i])
            if len(s.out) < s.req.max_new:     # max_new=0 emits nothing,
                s.out.append(tok)              # matching generate()
            if (tok == s.req.eos_id or len(s.out) >= s.req.max_new
                    or s.pos >= self.engine.max_len - 1):
                finished.append(i)
            else:
                cont.append(i)
        # retire completed rows BEFORE the pool decode.  Dense pool: the
        # batched dispatch writes every row, so a finished slot's KV must
        # be gathered first.  Paged pool: the finished row's pages must be
        # handed to the prefix cache (and its table row zeroed onto the
        # scratch page) before anything else dispatches.
        for i in finished:
            self._finish_slot(i)
        if not cont:
            return
        if self.spec:
            drafts = self._collect_drafts(cont)
            if any(drafts.values()):
                self._verify_round(cont, nxt, drafts)
                return
            # no slot drafted: the full (B, W, V) verify window would
            # commit exactly one token per row anyway — issue the cached
            # one-token pool decode instead (one extra cached trace, a
            # W-times smaller dispatch on novel text)
            self.engine.spec_draftless_rounds += 1
        self._decode_round(cont, nxt)

    def _decode_round(self, cont: list, nxt):
        """ONE one-token batched decode dispatch for the continuing rows
        (the non-speculative pool round, and the speculative scheduler's
        draft-less fallback)."""
        eng = self.engine
        B = self.max_active
        tok = np.zeros((B, 1), np.int32)
        pos = np.zeros((B,), np.int32)
        act = np.zeros((B,), bool)
        for i in cont:
            tok[i, 0] = nxt[i]
            pos[i] = self.slots[i].pos
            act[i] = True
            if eng.paged:
                # the write position may cross into a new block: grow
                # the slot's pages before the single pool dispatch
                s = self.slots[i]
                eng.ensure_page_for(s.pages, s.pos)
                self._ptab[i, :len(s.pages)] = s.pages
        if eng.paged:
            self._logits, eng.arena = eng._decode_batched(
                eng.params, eng.arena, jnp.asarray(self._ptab),
                jnp.asarray(tok), jnp.asarray(pos), jnp.asarray(act))
        else:
            self._logits, self._cache = eng._decode_batched(
                eng.params, self._cache,
                jnp.asarray(tok), jnp.asarray(pos), jnp.asarray(act))
        self.metrics["decode_calls"] += 1
        for i in cont:
            self.slots[i].pos += 1

    # ------------------------------------------------------------------
    # speculative n-gram decode (paged pool)
    # ------------------------------------------------------------------
    def _collect_drafts(self, cont: list) -> dict:
        """Feed each continuing slot's drafter and collect its proposal
        (possibly empty).  Separated from the verify dispatch so a round
        where NO slot drafted can fall back to the one-token pool decode
        instead of paying the full (B, W, V) verify window."""
        eng, W = self.engine, self._spec_w
        drafts: dict = {}
        for i in cont:
            s = self.slots[i]
            dr = s.drafter
            # feed the drafter every committed token (nxt is already in
            # s.out): its index covers prompt + generation so far
            n_new = len(s.req.tokens) + len(s.out) - len(dr.tokens)
            if n_new > 0:
                dr.extend(s.out[-n_new:])
            # drafting past max_new or max_len is wasted verify compute —
            # the accept loop below could never commit those tokens
            cap = min(W - 1, s.req.max_new - len(s.out),
                      eng.max_len - 1 - (s.pos + 1))
            drafts[i] = [int(t) for t in dr.draft(cap)]
            eng.spec_drafted += len(drafts[i])
        return drafts

    def _verify_round(self, cont: list, nxt, drafts: dict):
        """ONE multi-token verify dispatch for every continuing slot.

        Per row the window is [nxt, draft_1 .. draft_k] (k <= spec_k,
        ragged — rows with no n-gram match carry a bare one-token window)
        at positions pos .. pos+k.  The dispatch scatters the window's KV
        into the row's (append-only) pages and returns teacher-forced
        logits for every window position; the host accepts the longest
        draft prefix that matches greedy argmax, so outputs are token-
        identical to non-speculative decoding.  Rollback of rejected tail
        KV is pure bookkeeping: the row position simply doesn't advance
        over rejected tokens, the position mask hides their stale KV, and
        the next window overwrites it."""
        eng = self.engine
        B, W = self.max_active, self._spec_w
        tok = np.zeros((B, W), np.int32)
        pos = np.zeros((B,), np.int32)
        ntk = np.zeros((B,), np.int32)
        for i in cont:
            s = self.slots[i]
            d = drafts[i]
            n = 1 + len(d)
            tok[i, 0] = nxt[i]
            tok[i, 1:n] = d
            pos[i] = s.pos
            ntk[i] = n
            eng.ensure_page_for(s.pages, s.pos + n - 1)
            self._ptab[i, :len(s.pages)] = s.pages
        logits, eng.arena = eng._verify_paged_batched(
            eng.params, eng.arena, jnp.asarray(self._ptab),
            jnp.asarray(tok), jnp.asarray(pos), jnp.asarray(ntk))
        eng.spec_dispatches += 1
        self.metrics["decode_calls"] += 1
        greedy = np.asarray(jnp.argmax(logits, axis=-1))      # (B, W)
        sel = np.zeros((B,), np.int32)     # per-row next-logits window index
        keep = np.zeros((B,), bool)
        finished = []
        for i in cont:
            s = self.slots[i]
            s.pos += 1                     # nxt committed at the old pos
            accepted = 0
            done = False
            for j, t in enumerate(drafts[i]):
                if t != int(greedy[i, j]):
                    break                  # rejected: greedy diverged here
                # accepted draft == the model's own next greedy token;
                # same append+finish checks a non-spec round would run
                s.out.append(t)
                eng.spec_accepted += 1
                accepted += 1
                if (t == s.req.eos_id or len(s.out) >= s.req.max_new
                        or s.pos >= eng.max_len - 1):
                    done = True            # finishing token: appended but
                    break                  # its KV position stays unclaimed
                s.pos += 1
            eng.spec_tokens += 1 + accepted
            if done:
                finished.append(i)
            else:
                sel[i] = accepted          # logits after the last committed
                keep[i] = True             # window token
        new = jnp.take_along_axis(
            logits, jnp.asarray(sel)[:, None, None], axis=1)[:, 0]
        self._logits = jnp.where(jnp.asarray(keep)[:, None], new,
                                 self._logits)
        for i in finished:
            self._finish_slot(i)

    def _finish_slot(self, i: int):
        s = self.slots[i]
        self.slots[i] = None
        eng = self.engine
        # s.pos counts exactly the tokens whose KV is in the slot (the
        # finishing token was appended but never pool-decoded) — inserting
        # more would register block keys over positions that hold nothing
        full = ([int(t) for t in s.req.tokens] + s.out)[:s.pos]
        if eng.paged:
            eng.insert_prefix(full, s.pages)   # by reference, zero copy
            eng.release_pages(s.pages)
            self._ptab[i, :] = 0
        else:
            kv = eng._slot_read(self._cache, i)
            eng.prefix_cache.insert(full, kv, eng._cache_nbytes(kv))
        self.done.append(Result(s.req.req_id, s.out, ttft=s.ttft,
                                total=time.monotonic() - s.t_start,
                                cached_tokens=s.cached_tokens,
                                prompt_tokens=len(s.req.tokens)))
        self.metrics["completed"] += 1

    def run(self, max_rounds: int = 10_000):
        rounds = 0
        while (self.queue or self.active) and rounds < max_rounds:
            self.step()
            rounds += 1
        return self.done

    # ------------------------------------------------------------------
    def kv_bytes_in_use(self) -> int:
        """Physical KV footprint of this pool: live pages for a paged
        engine, the full dense pool allocation otherwise (the dense pool
        holds max_active x max_len regardless of occupancy — the contrast
        bench_throughput reports)."""
        if self.engine.paged:
            return self.engine.live_kv_bytes()
        return self.engine._cache_nbytes(self._cache)
