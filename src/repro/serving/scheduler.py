"""Slot-pool continuous-batching scheduler for the real engine.

The pool is a fixed ``(R, max_active, ...)``-batched decode cache
(models/lm.py slot helpers).  Admission prefills a request on the batch-1
path and *scatters* its cache into a free batch row; every ``step()`` then
issues ONE jitted ``decode(params, cache, tokens(B,1), pos(B,),
active(B,))`` dispatch for the whole pool — dead rows are masked, not
recompiled — and token selection / EOS handling is vectorized over the
batch.  Completion *gathers* the row back out for ``PrefixCache.insert``.
Admission keeps session stickiness semantics and a longest-prefix-match
preference (the node-local analogue of the HR-tree's group-level cache
affinity), probed read-only via ``PrefixCache.peek`` so the scan does not
skew hit-rate stats or LRU order.
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.serving.engine import RealEngine, Request, Result


@dataclass
class _Slot:
    req: Request
    pos: int
    out: list = field(default_factory=list)
    t_start: float = 0.0
    ttft: float = 0.0
    cached_tokens: int = 0


class Scheduler:
    def __init__(self, engine: RealEngine, max_active: int = 4,
                 prefer_cache_hits: bool = True):
        self.engine = engine
        self.max_active = max_active
        self.prefer_cache_hits = prefer_cache_hits
        self.queue: collections.deque = collections.deque()
        self.slots: list[Optional[_Slot]] = [None] * max_active
        self.done: list[Result] = []
        self.metrics = {"admitted": 0, "completed": 0, "queue_peak": 0,
                        "decode_calls": 0, "rounds": 0}
        # the slot pool: one batched cache pytree + one batched logits row
        # per slot, allocated once for the engine's max_len
        self._cache = engine.model.cache_zeros(max_active, engine.max_len)
        self._logits = jnp.zeros((max_active, engine.cfg.padded_vocab),
                                 jnp.float32)

    @property
    def active(self) -> list:
        return [s for s in self.slots if s is not None]

    def submit(self, req: Request):
        self.queue.append(req)
        self.metrics["queue_peak"] = max(self.metrics["queue_peak"],
                                         len(self.queue))

    # ------------------------------------------------------------------
    def _pick_request(self) -> Request:
        ix = 0
        if self.prefer_cache_hits and len(self.queue) > 1:
            best, best_len = 0, -1
            for i, r in enumerate(self.queue):
                ln, _ = self.engine.prefix_cache.peek(
                    [int(t) for t in r.tokens])
                if ln > best_len:
                    best, best_len = i, ln
            ix = best
        req = self.queue[ix]
        del self.queue[ix]
        return req

    def _admit_one(self):
        free = next((i for i, s in enumerate(self.slots) if s is None), None)
        if free is None or not self.queue:
            return
        req = self._pick_request()
        t0 = time.monotonic()
        eng = self.engine
        st = eng.prefill_request(req)
        self._cache = eng._slot_write(self._cache, st.cache, free)
        self._logits = self._logits.at[free].set(st.logits[0])
        self.slots[free] = _Slot(req, st.pos, t_start=t0,
                                 ttft=time.monotonic() - t0,
                                 cached_tokens=st.matched)
        self.metrics["admitted"] += 1

    # ------------------------------------------------------------------
    def step(self):
        """One continuous-batching round: admit into free slots, then ONE
        batched decode dispatch for every still-active slot."""
        while self.queue and any(s is None for s in self.slots):
            self._admit_one()
        active_ix = [i for i, s in enumerate(self.slots) if s is not None]
        if not active_ix:
            return
        self.metrics["rounds"] += 1
        nxt = np.asarray(jnp.argmax(self._logits, axis=-1))
        finished, cont = [], []
        for i in active_ix:
            s = self.slots[i]
            tok = int(nxt[i])
            if len(s.out) < s.req.max_new:     # max_new=0 emits nothing,
                s.out.append(tok)              # matching generate()
            if (tok == s.req.eos_id or len(s.out) >= s.req.max_new
                    or s.pos >= self.engine.max_len - 1):
                finished.append(i)
            else:
                cont.append(i)
        # gather completed rows BEFORE the pool decode: the batched dispatch
        # writes every row (dead rows included, masked only in attention
        # scores), so a finished slot's KV must be snapshot first
        for i in finished:
            self._finish_slot(i)
        if cont:
            B = self.max_active
            tok = np.zeros((B, 1), np.int32)
            pos = np.zeros((B,), np.int32)
            act = np.zeros((B,), bool)
            for i in cont:
                tok[i, 0] = nxt[i]
                pos[i] = self.slots[i].pos
                act[i] = True
            self._logits, self._cache = self.engine._decode_batched(
                self.engine.params, self._cache,
                jnp.asarray(tok), jnp.asarray(pos), jnp.asarray(act))
            self.metrics["decode_calls"] += 1
            for i in cont:
                self.slots[i].pos += 1

    def _finish_slot(self, i: int):
        s = self.slots[i]
        self.slots[i] = None
        eng = self.engine
        kv = eng._slot_read(self._cache, i)
        # s.pos counts exactly the tokens whose KV is in the slot row (the
        # finishing token was appended but never pool-decoded) — inserting
        # more would register block keys over positions that hold zeros
        full = ([int(t) for t in s.req.tokens] + s.out)[:s.pos]
        eng.prefix_cache.insert(full, kv, eng._cache_nbytes(kv))
        self.done.append(Result(s.req.req_id, s.out, ttft=s.ttft,
                                total=time.monotonic() - s.t_start,
                                cached_tokens=s.cached_tokens,
                                prompt_tokens=len(s.req.tokens)))
        self.metrics["completed"] += 1

    def run(self, max_rounds: int = 10_000):
        rounds = 0
        while (self.queue or self.active) and rounds < max_rounds:
            self.step()
            rounds += 1
        return self.done
