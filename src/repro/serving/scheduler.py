"""Continuous-batching scheduler for the real engine.

Admission queue -> active batch of up to ``max_active`` requests; each
scheduler tick runs one decode round for every active request (the
continuous-batching semantics of vLLM/SGLang, serialized on CPU), admits
new requests as slots free, applies session stickiness and a
longest-prefix-cache-match admission preference (the node-local analogue
of the HR-tree's group-level cache affinity).
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field
from typing import Optional

import jax.numpy as jnp

from repro.serving.engine import RealEngine, Request, Result


@dataclass
class _Active:
    req: Request
    cache: object
    logits: object
    pos: int
    out: list = field(default_factory=list)
    t_start: float = 0.0
    ttft: float = 0.0
    cached_tokens: int = 0


class Scheduler:
    def __init__(self, engine: RealEngine, max_active: int = 4,
                 prefer_cache_hits: bool = True):
        self.engine = engine
        self.max_active = max_active
        self.prefer_cache_hits = prefer_cache_hits
        self.queue: collections.deque = collections.deque()
        self.active: list[_Active] = []
        self.done: list[Result] = []
        self.metrics = {"admitted": 0, "completed": 0, "queue_peak": 0}

    def submit(self, req: Request):
        self.queue.append(req)
        self.metrics["queue_peak"] = max(self.metrics["queue_peak"],
                                         len(self.queue))

    # ------------------------------------------------------------------
    def _admit_one(self):
        if not self.queue or len(self.active) >= self.max_active:
            return
        ix = 0
        if self.prefer_cache_hits and len(self.queue) > 1:
            best, best_len = 0, -1
            for i, r in enumerate(self.queue):
                ln, _ = self.engine.prefix_cache.match(
                    [int(t) for t in r.tokens])
                if ln > best_len:
                    best, best_len = i, ln
            ix = best
        req = self.queue[ix]
        del self.queue[ix]
        t0 = time.monotonic()
        eng = self.engine
        toks = [int(t) for t in req.tokens]
        matched, entry = eng.prefix_cache.match(toks)
        if entry is not None and matched >= 8 and eng.partial_reuse:
            cache, pos, suffix = entry.handle, matched, toks[matched:]
        else:
            matched = 0
            boot = max(1, min(len(toks), 8))
            _, cache = eng._prefill(eng.params,
                                    jnp.asarray([toks[:boot]], jnp.int32))
            pos, suffix = boot, toks[boot:]
        logits = None
        for t in suffix:
            logits, cache = eng._decode(eng.params, cache,
                                        jnp.asarray([[t]], jnp.int32),
                                        jnp.asarray([pos], jnp.int32))
            pos += 1
        if logits is None:
            logits, cache = eng._decode(eng.params, cache,
                                        jnp.asarray([[toks[-1]]], jnp.int32),
                                        jnp.asarray([pos - 1], jnp.int32))
        self.active.append(_Active(req, cache, logits, pos,
                                   t_start=t0,
                                   ttft=time.monotonic() - t0,
                                   cached_tokens=matched))
        self.metrics["admitted"] += 1

    def step(self):
        """One continuous-batching round: admit + one decode per active."""
        while len(self.active) < self.max_active and self.queue:
            self._admit_one()
        finished = []
        for a in self.active:
            nxt = int(jnp.argmax(a.logits[0]))
            a.out.append(nxt)
            hit_eos = (nxt == a.req.eos_id
                       or len(a.out) >= a.req.max_new
                       or a.pos >= self.engine.max_len - 1)
            if hit_eos:
                finished.append(a)
                continue
            a.logits, a.cache = self.engine._decode(
                self.engine.params, a.cache,
                jnp.asarray([[nxt]], jnp.int32),
                jnp.asarray([a.pos], jnp.int32))
            a.pos += 1
        for a in finished:
            self.active.remove(a)
            full = [int(t) for t in a.req.tokens] + a.out
            self.engine.prefix_cache.insert(
                full, a.cache, self.engine._cache_nbytes(a.cache))
            self.done.append(Result(a.req.req_id, a.out, ttft=a.ttft,
                                    total=time.monotonic() - a.t_start,
                                    cached_tokens=a.cached_tokens,
                                    prompt_tokens=len(a.req.tokens)))
            self.metrics["completed"] += 1

    def run(self, max_rounds: int = 10_000):
        rounds = 0
        while (self.queue or self.active) and rounds < max_rounds:
            self.step()
            rounds += 1
        return self.done
