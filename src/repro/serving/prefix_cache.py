"""Node-local KV-prefix cache: block-hash radix index + LRU by bytes.

This is the *local* structure whose prefix set each model node summarizes
into its HR-tree broadcast (core/hrtree.py).  Lookup is O(len/B): the query
token stream is rolled into per-block chain hashes (strong SHA-based, no
false positives locally — the 8-bit compaction only happens in the HR-tree
sketch); entries register their KV handle at block granularity.
"""
from __future__ import annotations

import hashlib
import time
from dataclasses import dataclass, field
from typing import Optional, Sequence

BLOCK = 32


def _chain_hashes(tokens: Sequence[int], block: int = BLOCK) -> list[bytes]:
    """Chain hash at every complete block boundary."""
    out = []
    h = hashlib.sha256()
    n = len(tokens) // block
    for b in range(n):
        chunk = tokens[b * block:(b + 1) * block]
        h.update(",".join(str(int(t)) for t in chunk).encode())
        out.append(h.digest()[:16])
    return out


@dataclass
class Entry:
    handle: object            # engine-owned KV handle (cache pytree + meta)
    length: int               # tokens covered (block-aligned)
    nbytes: int
    keys: list = field(default_factory=list)   # chain keys registered
    last_used: float = field(default_factory=time.monotonic)
    hits: int = 0


class PrefixCache:
    def __init__(self, max_bytes: int = 1 << 30, block: int = BLOCK):
        self.max_bytes = max_bytes
        self.block = block
        self._by_chain: dict[bytes, Entry] = {}
        self.used_bytes = 0
        self.hits = 0
        self.misses = 0
        self.hit_tokens = 0
        self.total_tokens = 0
        # called with entry.handle when an entry becomes unreachable; a
        # paged engine hooks this to decref the entry's pages (pages stay
        # physically live while any in-flight request still aliases them)
        self.on_release = None
        # double-buffered sketch: the live buffer is grown incrementally
        # on insert (bloom bits are add-only), and an eviction marks it
        # dirty so the NEXT sketch_bytes() rebuilds from the surviving
        # chain keys — a sync after eviction never re-broadcasts the
        # evicted prefix's bits, and the steady state (no eviction since
        # the last sync) skips the O(entries x depths) rebuild entirely
        self._sketch = None
        self._sketch_dirty = True

    # ---- lookup ----
    def match(self, tokens: Sequence[int]) -> tuple[int, Optional[Entry]]:
        """Longest cached block-aligned prefix of ``tokens``.

        Every block depth of every inserted stream is indexed (radix
        semantics), so a request sharing only the first few blocks of a
        cached entry still reuses them."""
        chains = _chain_hashes(tokens, self.block)
        self.total_tokens += len(tokens)
        for d in range(len(chains), 0, -1):
            e = self._by_chain.get(chains[d - 1])
            if e is not None:
                e.last_used = time.monotonic()
                e.hits += 1
                self.hits += 1
                matched = min(d * self.block, e.length)
                self.hit_tokens += matched
                return matched, e
        self.misses += 1
        return 0, None

    def peek(self, tokens: Sequence[int]) -> tuple[int, Optional[Entry]]:
        """Read-only ``match``: no hit/miss counters, no LRU touch.

        For admission scans that probe many queued requests to *rank* them
        — only the winner's actual reuse should show up in stats."""
        chains = _chain_hashes(tokens, self.block)
        for d in range(len(chains), 0, -1):
            e = self._by_chain.get(chains[d - 1])
            if e is not None:
                return min(d * self.block, e.length), e
        return 0, None

    def entry_by_chain(self, digest: bytes) -> Optional[Entry]:
        """Entry registered under one chain digest, or None.  Read-only:
        no hit/miss counters, no LRU touch — the overlay's ``kv_fetch``
        handler probes by digest to decide whether a peer's replication
        request can still be served (the entry may have been evicted
        since the sketch broadcast that attracted the fetch)."""
        return self._by_chain.get(digest)

    # ---- insert ----
    def insert(self, tokens: Sequence[int], handle, nbytes: int):
        chains = _chain_hashes(tokens, self.block)
        length = (len(tokens) // self.block) * self.block
        self.insert_chains(chains, handle, nbytes, length)

    def insert_chains(self, chains: Sequence[bytes], handle, nbytes: int,
                      length: Optional[int] = None):
        """Insert an entry keyed by pre-computed BLOCK-chain digests.

        The cross-node page-migration importer lands here: ``kv_fetch``
        carried the request's digest chain, the holder's ``kv_pages``
        reply covers a prefix of it, and the importer registers the
        freshly scattered pages under those same digests — so the next
        admission's ``match`` aliases them with zero prefill work, exactly
        as if this node had prefilled the prefix itself."""
        chains = list(chains)
        if not chains:
            return
        length = (len(chains) * self.block) if length is None else length
        entry = Entry(handle, length, nbytes, keys=list(chains))
        self.used_bytes += nbytes
        for key in chains:
            old = self._by_chain.get(key)
            if old is not None and old is not entry:
                self._unlink(old, key)
            self._by_chain[key] = entry
        if self._sketch is not None and not self._sketch_dirty:
            from repro.core.forwarding import sketch_size_for
            if sketch_size_for(len(self._by_chain)) != self._sketch.nbytes:
                # key count crossed a ladder rung: the live buffer is now
                # undersized for the bounded-fp target — rebuild at the
                # next sync instead of growing stale bits in place
                self._sketch_dirty = True
            else:
                for key in chains:   # grow the live buffer in place:
                    self._sketch.add(key)    # adding bits never goes stale
        self._evict()

    def _release(self, e: Entry):
        """An entry just became unreachable: return its bytes and hand its
        handle to the release hook exactly once."""
        self.used_bytes -= e.nbytes
        if self.on_release is not None and e.handle is not None:
            self.on_release(e.handle)

    def _unlink(self, e: Entry, key: bytes):
        """Take one chain key away from ``e`` (the caller re-points it);
        once an entry holds no keys it is unreachable — release its bytes
        so accounting stays exact (used_bytes == sum of live entries)."""
        try:
            e.keys.remove(key)
        except ValueError:
            return
        if not e.keys:
            self._release(e)

    def _drop(self, e: Entry):
        for k in e.keys:
            if self._by_chain.get(k) is e:
                self._by_chain.pop(k)
        e.keys.clear()
        self._release(e)
        # bloom bits cannot be cleared in place: flip to the rebuild
        # buffer so the next sketch_bytes() drops the evicted digests
        self._sketch_dirty = True

    def _evict(self):
        if self.used_bytes <= self.max_bytes:
            return
        entries = sorted({id(e): e for e in self._by_chain.values()}.values(),
                         key=lambda e: e.last_used)
        for e in entries:
            if self.used_bytes <= self.max_bytes:
                break
            self._drop(e)

    def pop_lru(self) -> bool:
        """Drop the least-recently-used entry (allocator-pressure path: a
        paged engine evicts until enough pages come free).  False if
        empty."""
        entries = {id(e): e for e in self._by_chain.values()}.values()
        if not entries:
            return False
        self._drop(min(entries, key=lambda e: e.last_used))
        return True

    # ---- HR-tree / sketch sync ----
    def sketch_bytes(self) -> bytes:
        """Serialized bloom fingerprint of this cache's chain digests
        (core/forwarding.PrefixSketch), broadcast in every hr_sync so
        peers can route sibling requests to the prefix holder.

        Double-buffered for freshness: inserts grow the live buffer
        incrementally, an eviction marks it dirty and the next call
        rebuilds from the surviving keys — an evicted prefix stops
        attracting affinity routes after the next sync instead of
        lingering as stale bloom bits.  The rebuild picks its size from
        the power-of-two ladder (``forwarding.sketch_size_for``) by live
        key count, so the false-positive rate stays bounded under churny
        working sets instead of saturating a fixed 64-byte bloom; an
        insert that crosses a ladder rung marks the live buffer dirty the
        same way an eviction does."""
        from repro.core.forwarding import PrefixSketch
        if self._sketch is None or self._sketch_dirty:
            self._sketch = PrefixSketch.build(self._by_chain.keys())
            self._sketch_dirty = False
        return self._sketch.to_bytes()

    def cached_prefixes(self) -> list[tuple]:
        """(token-length, entry) view used to build HR-tree broadcasts —
        callers keep the original token streams alongside handles.
        Deduped by entry identity: an entry is indexed once per chain
        depth, and counting it once per key would inflate the node's
        advertised prefix count in every HR-tree broadcast."""
        uniq = {id(e): e for e in self._by_chain.values()}
        return [(e.length, e) for e in uniq.values()]

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    @property
    def token_hit_rate(self) -> float:
        return self.hit_tokens / self.total_tokens if self.total_tokens else 0.0
