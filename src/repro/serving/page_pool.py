"""Host-side page allocator for the node-wide paged KV pool.

The device side is a single ``(num_pages, BLOCK, n_kv, d_head)`` K/V arena
per layer (models/lm.py ``paged_arena_zeros``); THIS module owns which
physical pages are live and who references them.  Pages are refcounted so a
prefix-cache entry and any number of in-flight requests can alias the same
physical pages (zero-copy prefix sharing): ``alloc`` hands a page out at
refcount 1, every additional borrower ``incref``s, and a page returns to
the free list only when the last reference ``decref``s it.

Physical page 0 is reserved as a scratch ("null") page: inactive slot-pool
rows point their page tables at it so the single batched decode dispatch
has somewhere harmless to scatter masked rows' K/V — it is never allocated
and never read unmasked.

Pages also migrate ACROSS nodes (overlay kv_fetch/kv_pages): an export is
a read-only gather — no refcount moves on the holder — while an import
allocates fresh local pages whose initial reference is owned by the
importer's prefix-cache entry; a failed import releases every page it
allocated, so allocator invariants hold on both ends of the wire
(tests/test_page_pool_props.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable

NULL_PAGE = 0


class OutOfPages(RuntimeError):
    """Raised when ``alloc`` cannot satisfy a request (caller may evict
    prefix-cache entries to release pages and retry)."""


@dataclass(frozen=True)
class PagedHandle:
    """What a prefix-cache entry holds for a paged engine: physical page
    ids covering ``length`` block-aligned tokens.  Pure indices — the KV
    bytes live in the engine's arena and are never copied."""
    pages: tuple
    length: int               # tokens covered (block-aligned)

    def prefix(self, depth: int, block: int) -> "PagedHandle":
        """The handle's leading ``depth`` blocks as a new handle (pure
        index slice, no refcount movement).  Cross-node page migration
        exports by prefix: a ``kv_fetch`` may cover fewer blocks than the
        entry holds, and chain digests guarantee only the LEADING blocks
        match the request."""
        if not 1 <= depth <= len(self.pages):
            raise ValueError(f"depth {depth} of {len(self.pages)} pages")
        return PagedHandle(self.pages[:depth],
                           min(self.length, depth * block))


class PageAllocator:
    def __init__(self, num_pages: int):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is the scratch page)")
        self.num_pages = num_pages
        self._refs = [0] * num_pages
        self._refs[NULL_PAGE] = -1          # scratch: never allocatable
        # LIFO free list: recently freed pages are re-handed first (warm)
        self._free = list(range(num_pages - 1, 0, -1))

    # ---- queries ----
    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def used_count(self) -> int:
        return (self.num_pages - 1) - len(self._free)

    def refcount(self, page: int) -> int:
        return self._refs[page]

    # ---- lifecycle ----
    def alloc(self, n: int = 1) -> list:
        """n fresh pages at refcount 1; raises OutOfPages if unavailable."""
        if n < 0:
            raise ValueError(n)
        if n > len(self._free):
            raise OutOfPages(f"need {n} pages, {len(self._free)} free")
        out = [self._free.pop() for _ in range(n)]
        for p in out:
            self._refs[p] = 1
        return out

    def incref(self, pages: Iterable[int]):
        for p in pages:
            if p == NULL_PAGE:
                raise ValueError("cannot reference the scratch page")
            if self._refs[p] <= 0:
                raise ValueError(f"incref of free page {p}")
            self._refs[p] += 1

    def decref(self, pages: Iterable[int]):
        """Drop one reference per page; pages hitting 0 return to the free
        list.  Decref of an already-free page is a hard error (double
        free)."""
        for p in pages:
            if p == NULL_PAGE:
                raise ValueError("cannot release the scratch page")
            if self._refs[p] <= 0:
                raise ValueError(f"double free of page {p}")
            self._refs[p] -= 1
            if self._refs[p] == 0:
                self._free.append(p)

    def check(self):
        """Internal invariant: every non-scratch page is either free
        (refcount 0, on the free list once) or live (refcount > 0)."""
        free = set(self._free)
        assert len(free) == len(self._free), "free-list duplicate"
        for p in range(1, self.num_pages):
            if p in free:
                assert self._refs[p] == 0, f"page {p} free with refs"
            else:
                assert self._refs[p] > 0, f"page {p} leaked (refs 0, not free)"
