"""Localhost TCP transport: the same overlay node objects that run on the
simulator run over real sockets (the paper's prototype used TCP/TLS; TLS
termination is out of scope for the offline container — the S-IDA layer
already encrypts payload content end-to-end).

Each node gets a listening socket + a dispatcher thread; ``send`` opens
(and caches) outbound connections.  The ``TcpNet`` object quacks like
SimNet for the subset of the interface the overlay nodes use (send /
call_after via a timer thread / alive), so UserNode/ModelNode work
unmodified.
"""
from __future__ import annotations

import socket
import threading
import time
from dataclasses import dataclass

from repro.net import messages


@dataclass
class _Peer:
    host: str
    port: int


class TcpNet:
    def __init__(self):
        self.t0 = time.monotonic()
        self.nodes: dict = {}          # node_id -> handler
        self.addrs: dict = {}          # node_id -> _Peer
        self._servers: dict = {}
        self._conns: dict = {}
        self._lock = threading.Lock()
        self._timers: list = []
        self.delivered = 0
        self.dropped = 0
        self._closed = False

    # ---- SimNet-compatible surface ----
    @property
    def t(self) -> float:
        return time.monotonic() - self.t0

    def alive(self, node_id) -> bool:
        return node_id in self.nodes

    def call_after(self, dt: float, fn, *args):
        timer = threading.Timer(dt, fn, args)
        timer.daemon = True
        timer.start()
        self._timers.append(timer)

    def call_at(self, t: float, fn, *args):
        self.call_after(max(0.0, t - self.t), fn, *args)

    # ---- lifecycle ----
    def add_node(self, node_id, handler, host: str = "127.0.0.1"):
        srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        srv.bind((host, 0))
        srv.listen(32)
        port = srv.getsockname()[1]
        self.nodes[node_id] = handler
        self.addrs[node_id] = _Peer(host, port)
        self._servers[node_id] = srv
        th = threading.Thread(target=self._accept_loop,
                              args=(node_id, srv), daemon=True)
        th.start()

    def remove_node(self, node_id):
        self.nodes.pop(node_id, None)
        srv = self._servers.pop(node_id, None)
        if srv:
            try:
                srv.close()
            except OSError:
                pass

    def close(self):
        self._closed = True
        for t in self._timers:
            t.cancel()
        for nid in list(self._servers):
            self.remove_node(nid)
        with self._lock:
            for c in self._conns.values():
                try:
                    c.close()
                except OSError:
                    pass
            self._conns.clear()

    # ---- data path ----
    def send(self, src, dst, msg, size_bytes: int = 0):
        peer = self.addrs.get(dst)
        if peer is None or dst not in self.nodes:
            self.dropped += 1
            return
        wire = dict(msg)
        wire["_src"] = _encode_id(src)
        data = messages.encode(wire)
        try:
            conn = self._conn_to(src, dst, peer)
            conn.sendall(data)
        except OSError:
            self.dropped += 1

    def _conn_to(self, src, dst, peer: _Peer):
        key = (src, dst)
        with self._lock:
            c = self._conns.get(key)
            if c is None:
                c = socket.create_connection((peer.host, peer.port),
                                             timeout=5)
                self._conns[key] = c
            return c

    def _accept_loop(self, node_id, srv):
        while not self._closed:
            try:
                conn, _ = srv.accept()
            except OSError:
                return
            th = threading.Thread(target=self._recv_loop,
                                  args=(node_id, conn), daemon=True)
            th.start()

    def _recv_loop(self, node_id, conn):
        dec = messages.Decoder()
        while not self._closed:
            try:
                data = conn.recv(65536)
            except OSError:
                return
            if not data:
                return
            for msg in dec.feed(data):
                handler = self.nodes.get(node_id)
                if handler is None:
                    self.dropped += 1
                    continue
                src = _decode_id(msg.pop("_src", None))
                msg = _debytes(msg)
                self.delivered += 1
                try:
                    handler.on_message(self, src, msg)
                except Exception:
                    pass

    def run_until(self, t_end: float):
        """Wall-clock wait (keeps example/test code transport-agnostic)."""
        dt = t_end - self.t
        if dt > 0:
            time.sleep(dt)


def _encode_id(x):
    return ["b", x.hex()] if isinstance(x, bytes) else ["s", x]


def _decode_id(v):
    if v is None:
        return None
    tag, body = v
    return bytes.fromhex(body) if tag == "b" else body


def _debytes(msg):
    """msgpack round-trips py bytes fine; path_id hex strings unchanged."""
    return msg
