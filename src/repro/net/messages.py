"""Wire message schema + msgpack framing.

Every overlay message is a dict with a ``type`` field; this module is the
single source of truth for the schema (the simnet passes dicts in-process;
the TCP transport frames them with a length-prefixed msgpack encoding).
"""
from __future__ import annotations

import struct
from typing import Iterator

import msgpack

# message types and their required fields
SCHEMA = {
    "onion_create": ("blob",),
    "onion_create_fast": ("path_id", "chain", "origin", "hop"),
    "proxy_ack": ("path_id",),
    "clove_fwd": ("path_id", "dest_model", "clove", "msg_key"),
    "prompt_clove": ("clove", "proxy"),
    "response_clove": ("path_id",),
    "fwd_request": ("payload",),
    "hr_sync": ("from", "paths", "active", "hw"),
    # cross-node KV page migration (overlay/replicator.py): a node routed
    # a request with a fetch hint pulls the prefix pages from their
    # holder instead of re-prefilling them.
    #   kv_fetch   chains: list of BLOCK-chain digests (bytes), depth:
    #              how many leading blocks the fetcher wants
    #   kv_pages   ok: False = refusal (entry evicted / holder under
    #              pressure); True replies stream the msgpacked page
    #              buffer in ``total`` chunks of ``data`` bytes covering
    #              ``depth`` blocks (may be shallower than requested)
    "kv_fetch": ("from", "fetch_id", "chains", "depth"),
    "kv_pages": ("from", "fetch_id", "ok"),
}

# optional fields, (name -> accepted types) per message type: absent on
# older nodes, so validate() only type-checks them when present.  hr_sync
# carries the serving-pressure + prefix-affinity state the forwarding
# layer consumes:
#   kv_usage     int    prefix-cache bytes in use
#   kv_pressure  float  paged-arena fraction in use (0..1)
#   spec_accept_rate float  speculative-draft accept fraction (0..1)
#   sketch       bytes  core/forwarding.PrefixSketch over the node's
#                       cached block-chain digests (any ladder size)
# fwd_request may carry a replicate fetch hint (core/forwarding.decide):
#   kv_holder    the vetoed sketch holder to pull prefix pages from
#   kv_depth     int    hit depth in blocks
OPTIONAL = {
    "hr_sync": {"kv_usage": int, "kv_pressure": (int, float),
                "spec_accept_rate": (int, float),
                "sketch": (bytes, bytearray)},
    "fwd_request": {"kv_holder": (str, bytes, int), "kv_depth": int},
    "kv_pages": {"seq": int, "total": int, "depth": int,
                 "data": (bytes, bytearray)},
}


def validate(msg: dict) -> bool:
    t = msg.get("type")
    if t not in SCHEMA:
        return False
    if not all(f in msg for f in SCHEMA[t]):
        return False
    for f, typ in OPTIONAL.get(t, {}).items():
        if f in msg and msg[f] is not None and not isinstance(msg[f], typ):
            return False
    return True


def encode(msg: dict) -> bytes:
    body = msgpack.packb(msg, use_bin_type=True)
    return struct.pack("<I", len(body)) + body


class Decoder:
    """Incremental length-prefixed decoder for a TCP byte stream."""

    def __init__(self):
        self._buf = bytearray()

    def feed(self, data: bytes) -> Iterator[dict]:
        self._buf.extend(data)
        while len(self._buf) >= 4:
            (n,) = struct.unpack("<I", self._buf[:4])
            if len(self._buf) < 4 + n:
                return
            body = bytes(self._buf[4:4 + n])
            del self._buf[:4 + n]
            yield msgpack.unpackb(body, raw=False)
