"""Deterministic discrete-event network simulator.

The paper's testbed injects 100 ms of synthetic delay per packet on a real
cloud; we reproduce that regime deterministically: every node is an object
with ``on_message(net, src, msg)``, links have latency + bandwidth, nodes
can churn (join/leave/fail), malicious relays can drop.  Time is simulated
seconds; the same overlay code also runs over the localhost TCP transport
(net/tcp.py) — the simulator is the default because it is reproducible.
"""
from __future__ import annotations

import heapq
import itertools
import random
from dataclasses import dataclass, field
from typing import Callable


@dataclass(order=True)
class _Event:
    t: float
    seq: int
    fn: Callable = field(compare=False)
    args: tuple = field(compare=False, default=())


class SimNet:
    def __init__(self, default_latency: float = 0.1,
                 bandwidth_bps: float = 1e9, seed: int = 0):
        self.t = 0.0
        self._heap: list[_Event] = []
        self._seq = itertools.count()
        self.nodes: dict = {}
        self.default_latency = default_latency
        self.bandwidth = bandwidth_bps
        self.latency_overrides: dict = {}     # (src,dst) -> seconds
        self.rng = random.Random(seed)
        self.delivered = 0
        self.dropped = 0

    # ---- topology ----
    def add_node(self, node_id, handler):
        self.nodes[node_id] = handler

    def remove_node(self, node_id):
        self.nodes.pop(node_id, None)

    def alive(self, node_id) -> bool:
        return node_id in self.nodes

    def latency(self, src, dst) -> float:
        return self.latency_overrides.get((src, dst), self.default_latency)

    # ---- events ----
    def call_at(self, t: float, fn, *args):
        heapq.heappush(self._heap, _Event(t, next(self._seq), fn, args))

    def call_after(self, dt: float, fn, *args):
        self.call_at(self.t + dt, fn, *args)

    def send(self, src, dst, msg, size_bytes: int = 1024):
        """Schedule delivery of msg to dst's handler."""
        if dst not in self.nodes:
            self.dropped += 1
            return
        delay = self.latency(src, dst) + size_bytes / self.bandwidth
        self.call_after(delay, self._deliver, src, dst, msg)

    def _deliver(self, src, dst, msg):
        h = self.nodes.get(dst)
        if h is None:
            self.dropped += 1
            return
        self.delivered += 1
        h.on_message(self, src, msg)

    # ---- run loop ----
    def run_until(self, t_end: float):
        while self._heap and self._heap[0].t <= t_end:
            ev = heapq.heappop(self._heap)
            self.t = ev.t
            ev.fn(*ev.args)
        self.t = max(self.t, t_end)

    def run(self, max_events: int = 10_000_000):
        n = 0
        while self._heap and n < max_events:
            ev = heapq.heappop(self._heap)
            self.t = ev.t
            ev.fn(*ev.args)
            n += 1


class ChurnProcess:
    """Poisson churn: random user nodes leave / (re)join at ``rate`` per min."""

    def __init__(self, net: SimNet, pool: list, rate_per_min: float,
                 on_leave=None, on_join=None, seed: int = 1):
        self.net = net
        self.pool = pool
        self.rate = rate_per_min / 60.0
        self.rng = random.Random(seed)
        self.on_leave = on_leave
        self.on_join = on_join
        self.offline: dict = {}      # node_id -> saved handler

    def start(self):
        self.net.call_after(self._next_dt(), self._tick)

    def _next_dt(self) -> float:
        return self.rng.expovariate(self.rate) if self.rate > 0 else 1e18

    def _tick(self):
        if self.offline and self.rng.random() < 0.5:
            nid = self.rng.choice(list(self.offline))
            handler = self.offline.pop(nid)
            self.net.add_node(nid, handler)   # node rejoins the overlay
            if self.on_join:
                self.on_join(nid)
        elif self.pool:
            nid = self.pool[self.rng.randrange(len(self.pool))]
            if self.net.alive(nid):
                handler = self.net.nodes[nid]
                self.net.remove_node(nid)
                self.offline[nid] = handler
                if self.on_leave:
                    self.on_leave(nid)
        self.net.call_after(self._next_dt(), self._tick)
