from repro.models.lm import LM, build_model  # noqa: F401
