"""GQA attention: full-sequence (train/prefill), decode, ring-buffer SWA, cross.

The full-sequence path is *chunked over query blocks* (lax.scan) so the jnp
reference path lowered in the dry-run never materializes an (S, S) score
tensor — same O(S^2) FLOPs as flash attention with O(S * block_q) memory.
On real TPUs ``cfg.use_kernels`` swaps in the Pallas flash kernel
(kernels/flash_attention) for this path and kernels/decode_attention for the
decode path; the dry-run lowers this jnp path (Pallas does not lower for the
CPU stand-in backend).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed import constraints
from repro.models import common

NEG_INF = -2.0 ** 30  # large-but-finite: keeps softmax well-defined in bf16


# --------------------------------------------------------------------------
# Params
# --------------------------------------------------------------------------

def init_attn(cfg, key, cross: bool = False):
    d, nq, nkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    kq, kk, kv, ko = jax.random.split(key, 4)
    pd = cfg.params_dtype
    return {
        "wq": common.dense_init(kq, (d, nq, dh), d, pd),
        "wk": common.dense_init(kk, (d, nkv, dh), d, pd),
        "wv": common.dense_init(kv, (d, nkv, dh), d, pd),
        "wo": common.dense_init(ko, (nq, dh, d), nq * dh, pd),
    }


def _scale(cfg) -> float:
    return cfg.attn_scale if cfg.attn_scale else 1.0 / math.sqrt(cfg.d_head)


def _pad_heads_w(cfg, w, head_axis: int):
    """Zero-pad per GQA group so each group grows equally (preserves the
    original query-head -> kv-head assignment exactly)."""
    if not cfg.head_pad:
        return w
    nkv = cfg.n_kv_heads
    g = cfg.n_heads // nkv
    g_new = (cfg.n_heads + cfg.head_pad) // nkv
    shape = w.shape
    grouped = w.reshape(shape[:head_axis] + (nkv, g) + shape[head_axis + 1:])
    pad = [(0, 0)] * grouped.ndim
    pad[head_axis + 1] = (0, g_new - g)
    padded = jnp.pad(grouped, pad)
    return padded.reshape(shape[:head_axis] + (nkv * g_new,)
                          + shape[head_axis + 1:])


def q_heads(cfg) -> int:
    return cfg.n_heads + cfg.head_pad


def project_qkv(cfg, p, x, positions=None, rope: bool = True):
    dt = cfg.compute_dtype
    wq = _pad_heads_w(cfg, p["wq"].astype(dt), 1)
    q = jnp.einsum("bsd,dnh->bsnh", x, wq)
    k = jnp.einsum("bsd,dnh->bsnh", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dnh->bsnh", x, p["wv"].astype(dt))
    if rope and cfg.rope_theta > 0 and positions is not None:
        q = common.apply_rope(q, positions, cfg.rope_theta)
        k = common.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def project_kv_memory(cfg, p, mem):
    """Cross-attention K/V from a (B, T, d) memory (no RoPE)."""
    dt = cfg.compute_dtype
    k = jnp.einsum("btd,dnh->btnh", mem, p["wk"].astype(dt))
    v = jnp.einsum("btd,dnh->btnh", mem, p["wv"].astype(dt))
    return k, v


def out_proj(cfg, p, o):
    wo = _pad_heads_w(cfg, p["wo"].astype(cfg.compute_dtype), 0)
    return jnp.einsum("bsnh,nhd->bsd", o, wo)


# --------------------------------------------------------------------------
# Core blockwise attention
# --------------------------------------------------------------------------

def _expand_kv(k, n_heads):
    """(B, S, n_kv, h) -> (B, S, H, h) by repeating KV heads.

    Keeps the HEAD dim intact through the attention einsums so tensor
    parallelism shards it (reshaping H into (kv, group) factors breaks
    GSPMD head sharding — measured as replicated attention compute in the
    baseline; see EXPERIMENTS.md §Perf iteration 1)."""
    g = n_heads // k.shape[2]
    if g == 1:
        return k
    return jnp.repeat(k, g, axis=2)


def _gqa_scores(q, k, scale, cap):
    """q: (B, Sq, H, h); k: (B, Skv, H, h) -> (B, H, Sq, Skv)."""
    s = jnp.einsum("bqhd,bthd->bhqt", q, k) * scale
    s = common.softcap(s.astype(jnp.float32), cap)
    return s


def _gqa_out(probs, v):
    """probs: (B, H, Sq, Skv); v: (B, Skv, H, h) -> (B, Sq, H, h)."""
    return jnp.einsum("bhqt,bthd->bqhd", probs, v)


def full_attention(cfg, q, k, v, q_positions, kv_positions,
                   causal: bool = True, window: Optional[int] = None,
                   block_q: int = 512):
    """Chunked full-sequence attention.

    q: (B, Sq, nq, h); k, v: (B, Skv, nkv, h).
    q_positions: (B, Sq) or (Sq,); kv_positions: (B, Skv) or (Skv,).
    """
    B, Sq, nq, h = q.shape
    scale, cap = _scale(cfg), cfg.attn_softcap
    k = _expand_kv(k, nq)
    v = _expand_kv(v, nq)
    if q_positions.ndim == 1:
        q_positions = jnp.broadcast_to(q_positions[None], (B, Sq))
    if kv_positions.ndim == 1:
        kv_positions = jnp.broadcast_to(kv_positions[None], (B, k.shape[1]))

    nblk = max(1, math.ceil(Sq / block_q))
    pad = nblk * block_q - Sq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, ((0, 0), (0, pad)))
    qb_ = q.reshape(B, nblk, block_q, nq, h).transpose(1, 0, 2, 3, 4)
    qpos = q_positions.reshape(B, nblk, block_q).transpose(1, 0, 2)

    def body(carry, xs):
        qb, qp = xs                                   # (B, bq, H, h), (B, bq)
        s = _gqa_scores(qb, k, scale, cap)            # (B, H, bq, Skv) f32
        # pin scan residuals: batch on DP axes, heads on the TP axis —
        # GSPMD otherwise replicates the stacked softmax statistics that
        # the scan saves for backward (§Perf iteration 2)
        s = constraints.pin(s, ("batch", "model", None, None))
        m = jnp.ones((B, qp.shape[1], kv_positions.shape[1]), bool)
        if causal:
            m &= kv_positions[:, None, :] <= qp[:, :, None]
        if window is not None:
            m &= (qp[:, :, None] - kv_positions[:, None, :]) < window
        s = jnp.where(m[:, None, :, :], s, NEG_INF)
        probs = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        probs = constraints.pin(probs, ("batch", "model", None, None))
        ob = _gqa_out(probs, v)                       # (B, bq, H, h)
        return carry, constraints.pin(ob, ("batch", None, "model", None))

    _, ob = jax.lax.scan(body, (), (qb_, qpos))
    o = ob.transpose(1, 0, 2, 3, 4).reshape(B, nblk * block_q, nq, h)
    return o[:, :Sq]


# --------------------------------------------------------------------------
# Decode against caches
# --------------------------------------------------------------------------

def decode_attention(cfg, q, k_cache, v_cache, kv_positions, pos,
                     window: Optional[int] = None, active=None):
    """One-token decode.  q: (B, 1, nq, h); caches: (B, S, nkv, h);
    kv_positions: (B, S) absolute positions (-1 = empty); pos: (B,);
    active: optional (B,) bool — dead batch slots in a slot-pool decode get
    a fully-masked score row (uniform probs over finite NEG_INF, output
    discarded by the caller) instead of forcing a recompile per occupancy."""
    B, _, nq, h = q.shape
    scale, cap = _scale(cfg), cfg.attn_softcap
    kc = constraints.pin(_expand_kv(k_cache, nq),
                         ("batch", None, "model", None))
    vc = constraints.pin(_expand_kv(v_cache, nq),
                         ("batch", None, "model", None))
    s = _gqa_scores(q, kc, scale, cap)                # (B, H, 1, S)
    s = constraints.pin(s, ("batch", "model", None, None))
    valid = (kv_positions >= 0) & (kv_positions <= pos[:, None])
    if window is not None:
        valid &= (pos[:, None] - kv_positions) < window
    if active is not None:
        valid &= active[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    probs = jax.nn.softmax(s, axis=-1).astype(vc.dtype)
    return _gqa_out(probs, vc)                        # (B, 1, H, h)


def update_cache(k_cache, v_cache, kv_positions, k_new, v_new, slot):
    """Insert (B, 1, nkv, h) new K/V at per-batch ``slot`` (B,) int32."""
    B = k_cache.shape[0]
    bidx = jnp.arange(B)
    k_cache = k_cache.at[bidx, slot].set(k_new[:, 0])
    v_cache = v_cache.at[bidx, slot].set(v_new[:, 0])
    return k_cache, v_cache, kv_positions


# --------------------------------------------------------------------------
# Paged KV: block-granular arena indexed by per-request page tables
# --------------------------------------------------------------------------
#
# The arena is node-wide: one (num_pages, BLOCK, nkv, h) K and V slab per
# layer; a request's KV lives in the physical pages its page table names,
# so two requests sharing a prompt prefix alias the same pages instead of
# holding copies.  Physical page 0 is a scratch page (serving/page_pool):
# masked slot-pool rows scatter there and never read it back unmasked.

def gather_pages(arena, page_table):
    """arena: (P, BLOCK, nkv, h); page_table: (B, n_pg) int32 physical page
    per logical block -> (B, n_pg * BLOCK, nkv, h) dense per-request view.

    Logical position j of row b lives at arena[page_table[b, j // BLOCK],
    j % BLOCK]; unallocated table entries point at the scratch page and are
    masked by position in the attention that consumes the gather."""
    B, n_pg = page_table.shape
    blk = arena.shape[1]
    g = jnp.take(arena, page_table.reshape(-1), axis=0)
    return g.reshape(B, n_pg * blk, *arena.shape[2:])


def update_paged_cache(k_arena, v_arena, k_new, v_new, page_table, pos):
    """Scatter (B, 1, nkv, h) new K/V into the arena at each row's write
    page: physical page ``page_table[b, pos[b] // BLOCK]``, offset
    ``pos[b] % BLOCK``.  Rows whose table points at the scratch page
    (inactive slots) write there harmlessly."""
    B = page_table.shape[0]
    blk = k_arena.shape[1]
    bidx = jnp.arange(B)
    phys = page_table[bidx, pos // blk]               # (B,)
    off = pos % blk
    k_arena = k_arena.at[phys, off].set(k_new[:, 0])
    v_arena = v_arena.at[phys, off].set(v_new[:, 0])
    return k_arena, v_arena


def update_paged_cache_window(k_arena, v_arena, k_new, v_new, page_table,
                              pos, n_tok=None):
    """Scatter a (B, W, nkv, h) *speculation window* of new K/V into the
    arena: token m of row b lands at physical page ``page_table[b,
    (pos[b]+m) // BLOCK]``, offset ``(pos[b]+m) % BLOCK`` — the window may
    straddle a block boundary, unlike the one-token ``update_paged_cache``
    or the page-aligned prefill scatter.

    ``n_tok``: optional (B,) int32 count of real tokens per row (draft
    windows are ragged; dead slot-pool rows carry 0).  Positions at or
    beyond ``n_tok`` scatter onto the scratch page 0 so pad/dead tokens
    never touch a live page, and their table lookup is clamped so a row
    parked near ``max_len`` cannot index past its page table."""
    B, W = k_new.shape[:2]
    blk = k_arena.shape[1]
    n_pg = page_table.shape[1]
    positions = pos[:, None] + jnp.arange(W)[None]            # (B, W)
    blocks = jnp.clip(positions // blk, 0, n_pg - 1)
    phys = jnp.take_along_axis(page_table, blocks, axis=1)    # (B, W)
    if n_tok is not None:
        phys = jnp.where(jnp.arange(W)[None] < n_tok[:, None], phys, 0)
    off = positions % blk
    k_arena = k_arena.at[phys, off].set(k_new)
    v_arena = v_arena.at[phys, off].set(v_new)
    return k_arena, v_arena


def paged_decode_attention(cfg, q, k_arena, v_arena, page_table, pos,
                           window: Optional[int] = None, active=None):
    """One-token decode over paged KV.  q: (B, 1, nq, h); arenas:
    (P, BLOCK, nkv, h); page_table: (B, n_pg); pos: (B,).

    Gathers each row's pages into a dense (B, S, nkv, h) view and reuses
    ``decode_attention``: logical slot j holds absolute position j, so the
    position mask (<= pos, window) covers both the unwritten tail of the
    last page and unallocated table entries.  On TPU with ``cfg.
    use_kernels`` the gather happens inside the Pallas kernel via a
    scalar-prefetched page table (kernels/decode_attention/paged)."""
    B = q.shape[0]
    blk = k_arena.shape[1]
    S = page_table.shape[1] * blk
    if cfg.use_kernels and jax.default_backend() == "tpu":
        from repro.kernels.decode_attention import paged_decode_attention \
            as paged_op
        lengths = jnp.where(active, pos + 1, 0) if active is not None \
            else pos + 1
        o = paged_op(q[:, 0],                         # (B, H, h)
                     k_arena, v_arena, page_table, lengths,
                     window=window, softcap=cfg.attn_softcap or None,
                     scale=_scale(cfg))
        return o[:, None]                             # (B, 1, H, h)
    kd = gather_pages(k_arena, page_table)
    vd = gather_pages(v_arena, page_table)
    kv_pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    return decode_attention(cfg, q, kd, vd, kv_pos, pos,
                            window=window, active=active)


def paged_prefill_attention(cfg, q, k_arena, v_arena, page_table,
                            q_positions, window: Optional[int] = None,
                            block_q: int = 512, active=None):
    """Chunked-prefill attention over paged KV: the chunk's own K/V must
    already be scattered into the arena (update happens before attention,
    matching the decode path).  q: (B, C, nq, h); q_positions: (B, C).
    Causal masking over logical positions covers the not-yet-written tail
    of the write page and unallocated table entries.

    ``active``: optional (B,) bool mask for batched-admission prefill —
    rows whose divergence suffix ended in an earlier chunk step ride
    along with zeroed output (their write already went to the scratch
    page), so a shared chunk grid never recompiles per occupancy."""
    B = q.shape[0]
    blk = k_arena.shape[1]
    S = page_table.shape[1] * blk
    kd = gather_pages(k_arena, page_table)
    vd = gather_pages(v_arena, page_table)
    kv_pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    o = full_attention(cfg, q, kd, vd, q_positions, kv_pos,
                       causal=True, window=window, block_q=block_q)
    if active is not None:
        o = jnp.where(active[:, None, None, None], o, 0.0)
    return o


def attn_layer_forward(cfg, p, x, positions, window=None, causal=True,
                       memory=None, block_q: int = 512):
    """Full-sequence layer: self-attention, or cross-attention if memory."""
    if memory is None:
        q, k, v = project_qkv(cfg, p, x, positions)
        kv_pos = positions
    else:
        dt = cfg.compute_dtype
        wq = _pad_heads_w(cfg, p["wq"].astype(dt), 1)
        q = jnp.einsum("bsd,dnh->bsnh", x, wq)
        if cfg.rope_theta > 0:
            q = common.apply_rope(q, positions, cfg.rope_theta)
        k, v = project_kv_memory(cfg, p, memory)
        T = memory.shape[1]
        kv_pos = jnp.arange(T)
        causal, window = False, None
    o = full_attention(cfg, q, k, v, positions, kv_pos,
                       causal=causal, window=window, block_q=block_q)
    return out_proj(cfg, p, o)
