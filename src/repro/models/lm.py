"""Unified LM facade over all assigned architecture families.

Params are nested dicts; the repeating block ``cfg.pattern`` is stacked over
``cfg.n_repeats`` and executed with ``lax.scan`` (compact HLO for the
512-device dry-run).  Three entry points per model:

  apply(params, tokens, aux)            full causal logits      (train)
  prefill(params, tokens, aux, max_len) last logits + cache     (serving)
  decode(params, cache, tokens, pos)    next logits + cache     (serving)

Caches are pytrees stacked over repeats (tuple over pattern positions):
  attn      {"k","v"}: (R, B, size, n_kv, d_head); size = window or max_len
  cross     {"k","v"}: (R, B, T_mem, n_kv, d_head)  (static, no update)
  mamba     {"conv","h"}
  mlstm     {"C","n","m","conv"}
  slstm     {"h","c","n","m"}
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed import constraints
from repro.models import attention, common, mamba, moe, xlstm

MAX_LEARNED_POS = 65_536  # whisper-style learned positions table


# ==========================================================================
# Per-layer init / forward / prefill / decode
# ==========================================================================

def init_ffn(cfg, key):
    d, ff = cfg.d_model, cfg.d_ff
    pd = cfg.params_dtype
    if cfg.glu:
        kg, ku, kd = jax.random.split(key, 3)
        return {"w_gate": common.dense_init(kg, (d, ff), d, pd),
                "w_up": common.dense_init(ku, (d, ff), d, pd),
                "w_down": common.dense_init(kd, (ff, d), ff, pd)}
    ki, ko = jax.random.split(key, 2)
    return {"w_in": common.dense_init(ki, (d, ff), d, pd),
            "w_out": common.dense_init(ko, (ff, d), ff, pd)}


def ffn_forward(cfg, p, x):
    dt = cfg.compute_dtype
    act = common.act_fn(cfg.act)
    if cfg.glu:
        g = act(jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(dt)))
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(dt))
        return jnp.einsum("bsf,fd->bsd", g * u, p["w_down"].astype(dt))
    h = act(jnp.einsum("bsd,df->bsf", x, p["w_in"].astype(dt)))
    return jnp.einsum("bsf,fd->bsd", h, p["w_out"].astype(dt))


def init_layer(cfg, spec, key):
    km, kf, kn = jax.random.split(key, 3)
    p = {"norm1": common.init_norm(cfg, cfg.d_model)}
    if spec.mixer in ("attn", "cross_attn"):
        p["mixer"] = attention.init_attn(cfg, km, cross=spec.mixer == "cross_attn")
    elif spec.mixer == "mamba":
        p["mixer"] = mamba.init_mamba(cfg, km)
    elif spec.mixer == "mlstm":
        p["mixer"] = xlstm.init_mlstm(cfg, km)
    elif spec.mixer == "slstm":
        p["mixer"] = xlstm.init_slstm(cfg, km)
    if cfg.double_norm:
        p["norm1b"] = common.init_norm(cfg, cfg.d_model)
    if spec.ffn == "dense":
        p["norm2"] = common.init_norm(cfg, cfg.d_model)
        p["ffn"] = init_ffn(cfg, kf)
    elif spec.ffn == "moe":
        p["norm2"] = common.init_norm(cfg, cfg.d_model)
        p["ffn"] = moe.init_moe(cfg, kf)
    if cfg.double_norm and spec.ffn != "none":
        p["norm2b"] = common.init_norm(cfg, cfg.d_model)
    return p


def _ffn_block(cfg, spec, p, x, collect_aux=False):
    aux = 0.0
    if spec.ffn == "none":
        return x, aux
    h = common.apply_norm(cfg, p["norm2"], x)
    if spec.ffn == "dense":
        h = ffn_forward(cfg, p["ffn"], h)
    else:
        if collect_aux:
            h, aux = moe.moe_ffn(cfg, p["ffn"], h, return_aux=True)
        else:
            h = moe.moe_ffn(cfg, p["ffn"], h)
    if cfg.double_norm:
        h = common.apply_norm(cfg, p["norm2b"], h)
    return x + h, aux


def layer_forward_full(cfg, spec, p, x, positions, memory=None,
                       block_q=512, collect_aux=False):
    h = common.apply_norm(cfg, p["norm1"], x)
    if spec.mixer == "attn":
        h = attention.attn_layer_forward(cfg, p["mixer"], h, positions,
                                         window=spec.window, block_q=block_q)
    elif spec.mixer == "cross_attn":
        h = attention.attn_layer_forward(cfg, p["mixer"], h, positions,
                                         memory=memory, block_q=block_q)
    elif spec.mixer == "mamba":
        h = mamba.mamba_forward(cfg, p["mixer"], h)
    elif spec.mixer == "mlstm":
        h = xlstm.mlstm_forward(cfg, p["mixer"], h)
    elif spec.mixer == "slstm":
        h = xlstm.slstm_forward(cfg, p["mixer"], h)
    if cfg.double_norm:
        h = common.apply_norm(cfg, p["norm1b"], h)
    x = x + h
    return _ffn_block(cfg, spec, p, x, collect_aux)


def _attn_prefill(cfg, spec, p, x, positions, max_len, block_q):
    """Self-attention prefill: full forward + cache construction."""
    B, S, _ = x.shape
    q, k, v = attention.project_qkv(cfg, p, x, positions)
    o = attention.full_attention(cfg, q, k, v, positions, positions,
                                 causal=True, window=spec.window,
                                 block_q=block_q)
    out = attention.out_proj(cfg, p, o)
    size = min(spec.window, max_len) if spec.window else max_len
    nkv, dh = cfg.n_kv_heads, cfg.d_head
    kc = jnp.zeros((B, size, nkv, dh), k.dtype)
    vc = jnp.zeros((B, size, nkv, dh), v.dtype)
    tail = min(S, size)
    slots = (positions[-tail:] % size) if spec.window else positions[-tail:]
    kc = kc.at[:, slots].set(k[:, -tail:])
    vc = vc.at[:, slots].set(v[:, -tail:])
    return out, {"k": kc, "v": vc}


def _cross_prefill(cfg, p, x, positions, memory, block_q):
    out = attention.attn_layer_forward(cfg, p, x, positions, memory=memory,
                                       block_q=block_q)
    k, v = attention.project_kv_memory(cfg, p, memory)
    return out, {"k": k, "v": v}


def layer_prefill(cfg, spec, p, x, positions, max_len, memory=None,
                  block_q=512):
    h = common.apply_norm(cfg, p["norm1"], x)
    if spec.mixer == "attn":
        h, cache = _attn_prefill(cfg, spec, p["mixer"], h, positions,
                                 max_len, block_q)
    elif spec.mixer == "cross_attn":
        h, cache = _cross_prefill(cfg, p["mixer"], h, positions, memory,
                                  block_q)
    elif spec.mixer == "mamba":
        h, cache = mamba.mamba_forward(cfg, p["mixer"], h, return_cache=True)
    elif spec.mixer == "mlstm":
        h, cache = xlstm.mlstm_forward(cfg, p["mixer"], h, return_cache=True)
    elif spec.mixer == "slstm":
        h, cache = xlstm.slstm_forward(cfg, p["mixer"], h, return_cache=True)
    if cfg.double_norm:
        h = common.apply_norm(cfg, p["norm1b"], h)
    x = x + h
    x, _ = _ffn_block(cfg, spec, p, x)
    return x, cache


def _ring_kv_positions(pos, size, window):
    """Absolute position held by each ring slot after writing at ``pos``.

    slot j holds p = pos - ((pos - j) mod size); invalid if p < 0."""
    j = jnp.arange(size)
    p = pos[:, None] - ((pos[:, None] - j[None, :]) % size)
    return p  # (B, size); decode_attention masks p<0 and window


def layer_decode(cfg, spec, p, x, pos, cache, memory_unused=None,
                 active=None):
    """x: (B, 1, d); pos: (B,) absolute position of the new token;
    active: optional (B,) bool slot-pool mask (see decode_attention)."""
    B = x.shape[0]
    h = common.apply_norm(cfg, p["norm1"], x)
    if spec.mixer == "attn":
        q, k, v = attention.project_qkv(cfg, p["mixer"], h,
                                        pos[:, None], rope=True)
        size = cache["k"].shape[1]
        slot = (pos % size) if spec.window else pos
        kc, vc, _ = attention.update_cache(cache["k"], cache["v"], None,
                                           k, v, slot)
        if spec.window:
            kv_pos = _ring_kv_positions(pos, size, spec.window)
        else:
            kv_pos = jnp.broadcast_to(jnp.arange(size)[None], (B, size))
        o = attention.decode_attention(cfg, q, kc, vc, kv_pos, pos,
                                       window=spec.window, active=active)
        h = attention.out_proj(cfg, p["mixer"], o)
        cache = {"k": kc, "v": vc}
    elif spec.mixer == "cross_attn":
        dt = cfg.compute_dtype
        wq = attention._pad_heads_w(cfg, p["mixer"]["wq"].astype(dt), 1)
        q = jnp.einsum("bsd,dnh->bsnh", h, wq)
        if cfg.rope_theta > 0:
            q = common.apply_rope(q, pos[:, None], cfg.rope_theta)
        T = cache["k"].shape[1]
        kv_pos = jnp.zeros((B, T), jnp.int32)  # all valid (<= pos)
        o = attention.decode_attention(cfg, q, cache["k"], cache["v"],
                                       kv_pos, pos, active=active)
        h = attention.out_proj(cfg, p["mixer"], o)
    elif spec.mixer == "mamba":
        h, cache = mamba.mamba_decode(cfg, p["mixer"], h, cache)
    elif spec.mixer == "mlstm":
        h, cache = xlstm.mlstm_decode(cfg, p["mixer"], h, cache)
    elif spec.mixer == "slstm":
        h, cache = xlstm.slstm_decode(cfg, p["mixer"], h, cache)
    if cfg.double_norm:
        h = common.apply_norm(cfg, p["norm1b"], h)
    x = x + h
    x, _ = _ffn_block(cfg, spec, p, x)
    return x, cache


def layer_cache_zeros(cfg, spec, B, max_len, T_mem):
    dt = cfg.compute_dtype
    nkv, dh = cfg.n_kv_heads, cfg.d_head
    if spec.mixer == "attn":
        size = min(spec.window, max_len) if spec.window else max_len
        z = jnp.zeros((B, size, nkv, dh), dt)
        return {"k": z, "v": z}
    if spec.mixer == "cross_attn":
        z = jnp.zeros((B, T_mem, nkv, dh), dt)
        return {"k": z, "v": z}
    if spec.mixer == "mamba":
        return mamba.init_cache(cfg, B)
    if spec.mixer == "mlstm":
        return xlstm.empty_mlstm_state(cfg, B)
    if spec.mixer == "slstm":
        return xlstm.empty_slstm_state(cfg, B)
    raise ValueError(spec.mixer)


# ==========================================================================
# Paged KV: per-layer arena scatter/attend (pure-attention patterns only)
# ==========================================================================
#
# The paged pool replaces each request's dense ``max_len`` KV strip with
# block-granular pages in a node-wide (num_pages, BLOCK, nkv, h) arena per
# layer (stacked over repeats: (R, num_pages, BLOCK, nkv, h)).  A request
# is a page table — int32 physical page per logical block — so a prefix-
# cache hit aliases the holder's pages (refcount bump, serving/page_pool)
# instead of copying KV bytes, and pool memory scales with live tokens.
# Recurrent mixers (mamba/xLSTM) summarize the whole stream in O(1) state
# and have nothing to page; those families keep the dense slot pool.

def layer_decode_paged(cfg, spec, p, x, pos, arena, page_table,
                       active=None, write=True):
    """Paged analogue of ``layer_decode`` for ``attn`` mixers.  arena:
    {"k","v"}: (P, BLOCK, nkv, h); page_table: (B, n_pg); pos: (B,).
    ``write=False`` skips the arena scatter (query-only replay over fully
    cached tokens — never mutate pages another request may alias)."""
    h = common.apply_norm(cfg, p["norm1"], x)
    q, k, v = attention.project_qkv(cfg, p["mixer"], h, pos[:, None],
                                    rope=True)
    ka, va = arena["k"], arena["v"]
    if write:
        ka, va = attention.update_paged_cache(ka, va, k, v, page_table, pos)
    o = attention.paged_decode_attention(cfg, q, ka, va, page_table, pos,
                                         window=spec.window, active=active)
    h = attention.out_proj(cfg, p["mixer"], o)
    if cfg.double_norm:
        h = common.apply_norm(cfg, p["norm1b"], h)
    x = x + h
    x, _ = _ffn_block(cfg, spec, p, x)
    return x, {"k": ka, "v": va}


def layer_prefill_paged(cfg, spec, p, x, pos0, arena, page_table,
                        block_q=64, active=None):
    """One teacher-forced prefill chunk: scatter the chunk's K/V into its
    (freshly allocated) write pages, then attend over all pages.  x:
    (B, C, d) with C == BLOCK and ``pos0`` (B,) block-aligned, so the
    chunk covers exactly logical block ``pos0 // BLOCK`` of every row.

    ``active``: optional (B,) bool mask (batched admission over a shared
    chunk grid) — inactive rows scatter onto the scratch page 0 instead
    of a live page, so short-suffix rows never corrupt the arena while
    longer siblings still have chunks in flight."""
    B, C, _ = x.shape
    ka, va = arena["k"], arena["v"]
    blk = ka.shape[1]
    positions = pos0[:, None] + jnp.arange(C)[None]
    h = common.apply_norm(cfg, p["norm1"], x)
    q, k, v = attention.project_qkv(cfg, p["mixer"], h, positions,
                                    rope=True)
    phys = page_table[jnp.arange(B), pos0 // blk]      # (B,)
    if active is not None:
        phys = jnp.where(active, phys, 0)              # dead rows -> scratch
    ka = ka.at[phys].set(k)
    va = va.at[phys].set(v)
    o = attention.paged_prefill_attention(cfg, q, ka, va, page_table,
                                          positions, window=spec.window,
                                          block_q=block_q, active=active)
    h = attention.out_proj(cfg, p["mixer"], o)
    if cfg.double_norm:
        h = common.apply_norm(cfg, p["norm1b"], h)
    x = x + h
    x, _ = _ffn_block(cfg, spec, p, x)
    return x, {"k": ka, "v": va}


def layer_verify_paged(cfg, spec, p, x, pos, arena, page_table, n_tok=None):
    """One speculation-window layer step: scatter the window's K/V by
    token position (may straddle a block boundary), then attend causally
    *inside* the window.  x: (B, W, d) with W == spec_k + 1 — row b's
    window is [committed token, draft_1 .. draft_k] starting at absolute
    position ``pos[b]``; ``n_tok`` (B,) counts the real tokens per row
    (ragged drafts; 0 = dead slot) — pad/dead tokens scatter onto the
    scratch page and their outputs are zeroed.

    Teacher-forced verification: token m's hidden state attends exactly
    the KV a sequential decode at position pos+m would see (committed
    pages plus the window's own earlier tokens, just scattered), so the
    returned logits are the sequential greedy logits for every window
    position — acceptance is decided on the host by comparing drafts
    against argmax, and rejected tail KV needs no device rollback: pages
    are append-only per row and the position mask hides anything beyond
    the committed position until it is overwritten."""
    B, W, _ = x.shape
    positions = pos[:, None] + jnp.arange(W)[None]
    h = common.apply_norm(cfg, p["norm1"], x)
    q, k, v = attention.project_qkv(cfg, p["mixer"], h, positions,
                                    rope=True)
    ka, va = attention.update_paged_cache_window(
        arena["k"], arena["v"], k, v, page_table, pos, n_tok=n_tok)
    active = None if n_tok is None else n_tok > 0
    o = attention.paged_prefill_attention(cfg, q, ka, va, page_table,
                                          positions, window=spec.window,
                                          block_q=64, active=active)
    h = attention.out_proj(cfg, p["mixer"], o)
    if cfg.double_norm:
        h = common.apply_norm(cfg, p["norm1b"], h)
    x = x + h
    x, _ = _ffn_block(cfg, spec, p, x)
    return x, {"k": ka, "v": va}


def arena_gather_pages(arena, pages):
    """Gather physical pages out of a paged arena pytree: every
    {"k","v"} leaf (R, num_pages, BLOCK, nkv, h) -> (R, n, BLOCK, nkv, h)
    for the n requested pages, in order.

    The overlay's cross-node page migration uses this to lift a prefix
    entry's pages into a wire buffer (serving/engine.export_pages) — the
    same physical-page indexing ``attention.gather_pages`` applies per
    request, minus the logical-block reshape (wire pages stay
    block-granular)."""
    idx = jnp.asarray(pages, jnp.int32)
    return jax.tree.map(lambda a: a[:, idx], arena)


def arena_scatter_pages(arena, pages, blocks):
    """Inverse of ``arena_gather_pages``: write (R, n, BLOCK, nkv, h)
    block payloads into freshly allocated physical pages of every arena
    leaf (cast to the arena dtype — wire payloads may arrive fp16/int8-
    dequantized).  The caller owns the target pages (refcount 1); aliased
    pages are never scattered into.  Jit-friendly (``pages`` may be a
    traced index array): the serving engine wraps it with the arena
    donated so an import updates pages in place instead of copying the
    whole node-wide arena."""
    idx = jnp.asarray(pages, jnp.int32)

    def one(a, b):
        return a.at[:, idx].set(jnp.asarray(b, a.dtype))

    return jax.tree.map(one, arena, blocks)


# ==========================================================================
# Slot-pool cache helpers (continuous batching)
# ==========================================================================
#
# A slot pool is an ordinary decode cache built with ``cache_zeros(B=max_
# active, ...)``: leaves are (R, max_active, ...) with the batch on axis 1.
# A scheduler scatters each admitted request's single-request cache (batch
# dim 1, as produced by ``prefill``) into a free batch row, decodes the
# whole pool with ONE ``decode(..., active=mask)`` dispatch per round, and
# gathers the row back out on completion for prefix-cache insertion.  Both
# helpers accept a traced ``slot`` so a jitted wrapper compiles once.

def cache_slot_write(pool, single, slot):
    """Scatter a batch-1 cache pytree into batch row ``slot`` of ``pool``."""
    return jax.tree.map(lambda b, s: b.at[:, slot].set(s[:, 0]),
                        pool, single)


def cache_slot_read(pool, slot):
    """Gather batch row ``slot`` of ``pool`` as a batch-1 cache pytree."""
    return jax.tree.map(
        lambda b: jax.lax.dynamic_slice_in_dim(b, slot, 1, axis=1), pool)


# ==========================================================================
# Whisper-style encoder (bidirectional)
# ==========================================================================

_ENC_SPEC = None  # lazily built per call; encoder layers: attn + dense ffn


def _enc_spec():
    from repro.configs.base import LayerSpec
    return LayerSpec(mixer="attn", ffn="dense")


def init_encoder(cfg, key):
    spec = _enc_spec()
    keys = jax.random.split(key, cfg.n_enc_layers)
    layers = jax.vmap(lambda k: init_layer(cfg, spec, k))(keys)
    return {"layers": layers, "final_norm": common.init_norm(cfg, cfg.d_model)}


def encode(cfg, p, frames, block_q=512):
    """frames: (B, T, d) stub conv-frontend output -> (B, T, d)."""
    T = frames.shape[1]
    x = frames + common.sinusoidal_positions(T, cfg.d_model).astype(frames.dtype)
    positions = jnp.arange(T)
    spec = _enc_spec()

    def body(x, lp):
        h = common.apply_norm(cfg, lp["norm1"], x)
        q, k, v = attention.project_qkv(cfg, lp["mixer"], h, positions,
                                        rope=False)
        o = attention.full_attention(cfg, q, k, v, positions, positions,
                                     causal=False, block_q=block_q)
        x = x + attention.out_proj(cfg, lp["mixer"], o)
        x, _ = _ffn_block(cfg, spec, lp, x)
        return x, None

    x, _ = jax.lax.scan(body, x, p["layers"])
    return common.apply_norm(cfg, p["final_norm"], x)


# ==========================================================================
# Model facade
# ==========================================================================

class LM:
    def __init__(self, cfg):
        self.cfg = cfg

    # ---------------- params ----------------
    def init(self, key):
        cfg = self.cfg
        ke, kb, kh, kenc, kpos = jax.random.split(key, 5)
        params = {
            "embed": common.embed_init(ke, (cfg.padded_vocab, cfg.d_model),
                                       cfg.params_dtype),
            "final_norm": common.init_norm(cfg, cfg.d_model),
        }
        R = cfg.n_repeats
        keys = jax.random.split(kb, R)

        def one_repeat(k):
            ks = jax.random.split(k, len(cfg.pattern))
            return tuple(init_layer(cfg, spec, ks[i])
                         for i, spec in enumerate(cfg.pattern))

        params["blocks"] = jax.vmap(one_repeat)(keys)
        if not cfg.tie_embeddings:
            params["lm_head"] = common.embed_init(
                kh, (cfg.padded_vocab, cfg.d_model), cfg.params_dtype)
        if cfg.is_encdec:
            params["encoder"] = init_encoder(cfg, kenc)
        if cfg.rope_theta <= 0:
            params["pos_embed"] = common.embed_init(
                kpos, (MAX_LEARNED_POS, cfg.d_model), cfg.params_dtype)
        return params

    def param_specs(self):
        key = jax.random.PRNGKey(0)
        return jax.eval_shape(self.init, key)

    # ---------------- helpers ----------------
    def _embed(self, params, tokens, positions):
        cfg = self.cfg
        x = common.take_embedding(params["embed"].astype(cfg.compute_dtype),
                                  tokens, cfg.embed_scale)
        if cfg.rope_theta <= 0:
            pe = jnp.take(params["pos_embed"].astype(cfg.compute_dtype),
                          jnp.minimum(positions, MAX_LEARNED_POS - 1), axis=0)
            x = x + pe
        # re-pin batch sharding: the embed table's FSDP sharding otherwise
        # propagates into activations (see distributed/constraints.py)
        return constraints.constrain_batch(x)

    def _logits(self, params, x):
        cfg = self.cfg
        head = (params["embed"] if cfg.tie_embeddings
                else params["lm_head"]).astype(cfg.compute_dtype)
        logits = jnp.einsum("...d,vd->...v", x, head)
        logits = common.softcap(logits.astype(jnp.float32),
                                cfg.final_softcap)
        if cfg.padded_vocab != cfg.vocab:
            pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab
            logits = jnp.where(pad_mask, -1e30, logits)
        return logits

    def _memory(self, params, aux, block_q=512):
        cfg = self.cfg
        if cfg.is_encdec:
            return encode(cfg, params["encoder"], aux["frames"], block_q)
        if cfg.n_image_tokens:
            return aux["image_embeds"]
        return None

    # ---------------- full forward (train) ----------------
    def apply(self, params, tokens, aux=None, remat=False, block_q=512,
              collect_aux=False):
        cfg = self.cfg
        B, S = tokens.shape
        positions = jnp.arange(S)
        x = self._embed(params, tokens, positions)
        memory = self._memory(params, aux or {}, block_q)

        def body(x, bp):
            aux_sum = 0.0
            for i, spec in enumerate(cfg.pattern):
                x, a = layer_forward_full(cfg, spec, bp[i], x, positions,
                                          memory=memory, block_q=block_q,
                                          collect_aux=collect_aux)
                aux_sum = aux_sum + a
            return constraints.constrain_batch(x), aux_sum

        if remat:
            from repro.distributed.remat import wrap
            body = wrap(body, "full" if remat is True else remat)
        x, auxs = jax.lax.scan(body, x, params["blocks"])
        x = common.apply_norm(cfg, params["final_norm"], x)
        logits = self._logits(params, x)
        if collect_aux:
            return logits, jnp.sum(auxs)
        return logits

    # ---------------- prefill ----------------
    def prefill(self, params, tokens, aux=None, max_len=None, block_q=512):
        cfg = self.cfg
        B, S = tokens.shape
        max_len = max_len or S
        positions = jnp.arange(S)
        x = self._embed(params, tokens, positions)
        memory = self._memory(params, aux or {}, block_q)

        def body(x, bp):
            caches = []
            for i, spec in enumerate(cfg.pattern):
                x, c = layer_prefill(cfg, spec, bp[i], x, positions, max_len,
                                     memory=memory, block_q=block_q)
                caches.append(c)
            return constraints.constrain_batch(x), tuple(caches)

        x, cache = jax.lax.scan(body, x, params["blocks"])
        x = common.apply_norm(cfg, params["final_norm"], x)
        logits = self._logits(params, x[:, -1])
        return logits, cache

    # ---------------- decode ----------------
    def decode(self, params, cache, tokens, pos, active=None):
        """tokens: (B, 1); pos: (B,) absolute position of the new token.

        ``active`` is an optional (B,) bool slot-pool mask: with a fixed
        max-batch cache, a partially occupied pool decodes with dead rows
        masked instead of recompiling for every occupancy level."""
        cfg = self.cfg
        x = self._embed(params, tokens, pos[:, None])

        def body(x, xs):
            bp, cr = xs
            new = []
            for i, spec in enumerate(cfg.pattern):
                x, c = layer_decode(cfg, spec, bp[i], x, pos, cr[i],
                                    active=active)
                new.append(c)
            return constraints.constrain_batch(x), tuple(new)

        x, cache = jax.lax.scan(body, x, (params["blocks"], cache))
        x = common.apply_norm(cfg, params["final_norm"], x)
        logits = self._logits(params, x[:, -1])
        return logits, cache

    # ---------------- paged serving (pure-attention patterns) ----------
    def supports_paging(self) -> bool:
        """Only attention KV has per-position state to page; recurrent and
        cross-attention mixers keep the dense slot pool."""
        return all(s.mixer == "attn" for s in self.cfg.pattern)

    def paged_arena_zeros(self, num_pages, block):
        """Node-wide paged KV arena: per pattern position {"k","v"} leaves
        of shape (R, num_pages, BLOCK, nkv, d_head).  Page 0 is the
        scratch page (serving/page_pool.NULL_PAGE)."""
        cfg = self.cfg
        assert self.supports_paging(), cfg.name
        z = jnp.zeros((cfg.n_repeats, num_pages, block, cfg.n_kv_heads,
                       cfg.d_head), cfg.compute_dtype)
        return tuple({"k": z, "v": z} for _ in cfg.pattern)

    def prefill_paged(self, params, arena, page_tables, tokens, pos0,
                      active=None):
        """One teacher-forced chunk of prompt prefill over the paged pool.

        tokens: (B, C) with C == BLOCK; pos0: (B,) block-aligned chunk
        start.  Scatters the chunk's K/V into each row's write page and
        returns logits for EVERY chunk position ((B, C, V) — the caller
        picks the last real token's row; pad tail K/V is overwritten by
        later writes before any mask exposes it), plus the updated arena.

        ``active`` is an optional (B,) bool mask for batched admission:
        all admitted requests' divergence suffixes march through ONE
        shared chunk grid, rows whose suffix already ended are masked
        (scratch-page writes, zeroed output) — K co-routed siblings cost
        max(chunks) dispatches instead of sum(chunks)."""
        cfg = self.cfg
        B, C = tokens.shape
        positions = pos0[:, None] + jnp.arange(C)[None]
        x = self._embed(params, tokens, positions)

        def body(x, xs):
            bp, ar = xs
            new = []
            for i, spec in enumerate(cfg.pattern):
                x, a = layer_prefill_paged(cfg, spec, bp[i], x, pos0,
                                           ar[i], page_tables,
                                           active=active)
                new.append(a)
            return constraints.constrain_batch(x), tuple(new)

        x, arena = jax.lax.scan(body, x, (params["blocks"], arena))
        x = common.apply_norm(cfg, params["final_norm"], x)
        return self._logits(params, x), arena

    def decode_paged(self, params, arena, page_tables, tokens, pos,
                     active=None, write=True):
        """Paged analogue of ``decode``: tokens (B, 1), pos (B,),
        page_tables (B, n_pg) physical page per logical block.  With
        ``write=False`` the arena is returned untouched (query-only replay
        for full prefix hits — aliased pages are never mutated)."""
        cfg = self.cfg
        x = self._embed(params, tokens, pos[:, None])

        def body(x, xs):
            bp, ar = xs
            new = []
            for i, spec in enumerate(cfg.pattern):
                x, a = layer_decode_paged(cfg, spec, bp[i], x, pos, ar[i],
                                          page_tables, active=active,
                                          write=write)
                new.append(a)
            return constraints.constrain_batch(x), tuple(new)

        x, arena = jax.lax.scan(body, x, (params["blocks"], arena))
        x = common.apply_norm(cfg, params["final_norm"], x)
        logits = self._logits(params, x[:, -1])
        return logits, arena

    def verify_paged(self, params, arena, page_tables, tokens, pos,
                     n_tok=None):
        """Speculative multi-token verify over the paged pool.

        tokens: (B, W) — per row, the committed next token followed by up
        to W-1 draft tokens; pos: (B,) absolute position of the window's
        first token; n_tok: (B,) real tokens per row (ragged drafts, 0 =
        masked slot).  Extends ``decode_paged`` to a W-token window in ONE
        dispatch: the window's K/V is scattered by token position (pad and
        dead rows go to the scratch page) and attention is causal inside
        the window, so the returned (B, W, V) logits equal W sequential
        single-token decodes — the scheduler accepts the longest draft
        prefix matching greedy argmax and rolls back rejected tail KV by
        simply not advancing the row position (append-only pages)."""
        cfg = self.cfg
        B, W = tokens.shape
        positions = pos[:, None] + jnp.arange(W)[None]
        x = self._embed(params, tokens, positions)

        def body(x, xs):
            bp, ar = xs
            new = []
            for i, spec in enumerate(cfg.pattern):
                x, a = layer_verify_paged(cfg, spec, bp[i], x, pos, ar[i],
                                          page_tables, n_tok=n_tok)
                new.append(a)
            return constraints.constrain_batch(x), tuple(new)

        x, arena = jax.lax.scan(body, x, (params["blocks"], arena))
        x = common.apply_norm(cfg, params["final_norm"], x)
        return self._logits(params, x), arena

    # ---------------- cache scaffolding ----------------
    def cache_zeros(self, B, max_len, T_mem=0):
        cfg = self.cfg
        R = cfg.n_repeats

        def stack(c):
            return jax.tree.map(lambda a: jnp.broadcast_to(
                a[None], (R,) + a.shape), c)

        return tuple(stack(layer_cache_zeros(cfg, spec, B, max_len, T_mem))
                     for spec in cfg.pattern)

    def cache_specs(self, B, max_len, T_mem=0):
        return jax.eval_shape(lambda: self.cache_zeros(B, max_len, T_mem))


def build_model(cfg) -> LM:
    return LM(cfg)


# ==========================================================================
# Loss
# ==========================================================================

def lm_loss(cfg, model: LM, params, tokens, labels, aux=None, remat=True,
            block_q=512):
    """Mean next-token cross-entropy; labels < 0 are masked.

    Returns (loss, metrics).  MoE archs add the Switch load-balance aux."""
    collect = cfg.moe is not None
    out = model.apply(params, tokens, aux=aux, remat=remat, block_q=block_q,
                      collect_aux=collect)
    logits, moe_aux = out if collect else (out, 0.0)
    logits = logits.astype(jnp.float32)
    mask = (labels >= 0).astype(jnp.float32)
    safe_labels = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, safe_labels[..., None],
                             axis=-1)[..., 0] - logz
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = -(ll * mask).sum() / denom
    total = loss + 0.01 * moe_aux
    return total, {"ce": loss, "moe_aux": moe_aux,
                   "tokens": mask.sum()}
