"""xLSTM blocks: chunkwise-parallel mLSTM and sequential sLSTM.

mLSTM = matrix-memory LSTM:
    C_t = f_t C_{t-1} + i_t k_t v_t^T,  n_t = f_t n_{t-1} + i_t k_t,
    h_t = (q'_t C_t) / max(|q'_t n_t|, exp(-m_t)),  q' = q/sqrt(dh)
with exp input gates, sigmoid forget gates (in log space) and running-max
stabilizer m.  We use the stabilized *chunkwise* formulation: within a chunk
of length L the gate products form an (L, L) lower-triangular matrix (MXU
shaped); across chunks a lax.scan carries (C~, n~, m) where
true C = C~ * exp(m).  All gate math in f32.

sLSTM = scalar-memory LSTM with block-diagonal recurrent matrices R per head;
the hidden-state feedback makes it inherently sequential, so it is a
lax.scan over time (1/8 of layers at the paper-accurate 7:1 ratio).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import common


def mlstm_dims(cfg):
    di = int(cfg.xlstm.proj_factor_mlstm * cfg.d_model)
    H = cfg.n_heads
    return di, H, di // H


# ==========================================================================
# mLSTM
# ==========================================================================

def init_mlstm(cfg, key):
    d = cfg.d_model
    di, H, dh = mlstm_dims(cfg)
    kup, kconv, kq, kk, kif, ko = jax.random.split(key, 6)
    pd = cfg.params_dtype
    return {
        "w_up": common.dense_init(kup, (d, 2 * di), d, pd),    # xi | z
        "conv": common.dense_init(kconv, (cfg.xlstm.conv_width, di),
                                  cfg.xlstm.conv_width, pd),
        "w_q": common.dense_init(kq, (di, di), di, pd),
        "w_k": common.dense_init(kk, (di, di), di, pd),
        "w_if": common.dense_init(kif, (di, 2 * H), di, pd),   # i~ | f~
        "if_bias": jnp.concatenate([jnp.zeros((H,)),
                                    jnp.linspace(3.0, 6.0, H)]).astype(pd),
        "head_norm": jnp.ones((H, dh), pd),
        "w_down": common.dense_init(ko, (di, d), di, pd),
    }


def _conv_silu(cfg, w, x, state=None):
    dc = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], dc - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None, :] for i in range(dc))
    return jax.nn.silu(out), xp[:, xp.shape[1] - (dc - 1):]


def _mlstm_proj(cfg, p, x, conv_state=None):
    dt = cfg.compute_dtype
    di, H, dh = mlstm_dims(cfg)
    up = jnp.einsum("bsd,de->bse", x, p["w_up"].astype(dt))
    xi, z = jnp.split(up, 2, axis=-1)
    xc, new_conv = _conv_silu(cfg, p["conv"].astype(dt), xi, conv_state)
    B, S, _ = x.shape
    scale = 1.0 / math.sqrt(dh)
    q = (jnp.einsum("bse,ef->bsf", xc, p["w_q"].astype(dt)) * scale
         ).reshape(B, S, H, dh)
    k = jnp.einsum("bse,ef->bsf", xc, p["w_k"].astype(dt)).reshape(B, S, H, dh)
    v = xi.reshape(B, S, H, dh)
    gates = (jnp.einsum("bse,eg->bsg", xc, p["w_if"].astype(dt))
             .astype(jnp.float32) + p["if_bias"].astype(jnp.float32))
    log_i, f_raw = jnp.split(gates, 2, axis=-1)                # (B,S,H)
    log_f = jax.nn.log_sigmoid(f_raw)
    return q, k, v, log_i, log_f, z, new_conv


def _finish(cfg, p, h, z):
    """h: (B,S,H,dh) -> (B,S,d): headwise RMS norm, gate, down-proj."""
    dt = cfg.compute_dtype
    di, H, dh = mlstm_dims(cfg)
    hf = h.astype(jnp.float32)
    var = jnp.mean(jnp.square(hf), axis=-1, keepdims=True)
    hn = hf * jax.lax.rsqrt(var + 1e-6) * p["head_norm"].astype(jnp.float32)
    hn = hn.astype(dt).reshape(h.shape[0], h.shape[1], di)
    return jnp.einsum("bse,ed->bsd", hn * jax.nn.silu(z),
                      p["w_down"].astype(dt))


def empty_mlstm_state(cfg, batch):
    di, H, dh = mlstm_dims(cfg)
    return {
        "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, cfg.xlstm.conv_width - 1, di),
                          cfg.compute_dtype),
    }


def mlstm_forward(cfg, p, x, cache=None, return_cache=False, chunk=64):
    """Chunkwise-parallel mLSTM.  x: (B, S, d) -> (B, S, d)."""
    di, H, dh = mlstm_dims(cfg)
    B, S, _ = x.shape
    conv_state = cache["conv"] if cache is not None else None
    q, k, v, log_i, log_f, z, new_conv = _mlstm_proj(cfg, p, x, conv_state)

    L = min(chunk, S)
    nchunks = math.ceil(S / L)
    pad = nchunks * L - S
    if pad:
        q, k, v = (jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
                   for a in (q, k, v))
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)),
                        constant_values=-60.0)
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))

    def to_chunks(a):
        return a.reshape(B, nchunks, L, *a.shape[2:]).swapaxes(0, 1)

    qc, kc, vc, ic, fc = map(to_chunks, (q, k, v, log_i, log_f))
    if cache is not None:
        st0 = {n: cache[n] for n in ("C", "n", "m")}
    else:
        e = empty_mlstm_state(cfg, B)
        st0 = {n: e[n] for n in ("C", "n", "m")}

    def chunk_step(st, xs):
        qb, kb, vb, gi, gf = xs       # (B,L,H,dh) x3, (B,L,H) x2
        qf, kf, vf = (a.astype(jnp.float32) for a in (qb, kb, vb))
        b = jnp.cumsum(gf, axis=1)    # inclusive cumulative log f
        gmb = jax.lax.cummax(gi - b, axis=1)
        m_new = b + jnp.maximum(st["m"][:, None], gmb)         # (B,L,H)
        inter = jnp.exp(b + st["m"][:, None] - m_new)          # (B,L,H)
        # gate[s,t] = exp(b_s - b_t + g_t - m_new[s]),  t <= s
        dmat = (b[:, :, None] - b[:, None, :] + gi[:, None, :]
                - m_new[:, :, None])                           # (B,S,T,H)
        mask = jnp.tril(jnp.ones((L, L), bool))
        gate = jnp.where(mask[None, :, :, None], jnp.exp(dmat), 0.0)
        sc = jnp.einsum("bshe,bthe->bsth", qf, kf)             # q'.k
        att = gate * sc                                        # (B,S,T,H)
        num = (jnp.einsum("bsth,bthe->bshe", att, vf)
               + inter[..., None] * jnp.einsum("bshe,bhef->bshf", qf, st["C"]))
        qn = jnp.einsum("bshe,bhe->bsh", qf, st["n"])
        den = att.sum(2) + inter * qn                          # (B,S,H)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
        # ---- state update at chunk end ----
        m_next = m_new[:, -1]
        w_end = gate[:, -1]                                    # (B,T,H)
        C_next = (inter[:, -1][:, :, None, None] * st["C"]
                  + jnp.einsum("bth,bthe,bthf->bhef", w_end, kf, vf))
        n_next = (inter[:, -1][..., None] * st["n"]
                  + jnp.einsum("bth,bthe->bhe", w_end, kf))
        return {"C": C_next, "n": n_next, "m": m_next}, h

    st_fin, hs = jax.lax.scan(chunk_step, st0, (qc, kc, vc, ic, fc))
    h = hs.swapaxes(0, 1).reshape(B, nchunks * L, H, dh)[:, :S]
    out = _finish(cfg, p, h.astype(cfg.compute_dtype), z)
    if return_cache:
        return out, {**st_fin, "conv": new_conv}
    return out


def mlstm_decode(cfg, p, x, cache):
    """Single-token decode.  x: (B, 1, d)."""
    q, k, v, log_i, log_f, z, new_conv = _mlstm_proj(cfg, p, x, cache["conv"])
    qf, kf, vf = (a[:, 0].astype(jnp.float32) for a in (q, k, v))  # (B,H,dh)
    gi, gf = log_i[:, 0], log_f[:, 0]                              # (B,H)
    m_new = jnp.maximum(gf + cache["m"], gi)
    f_s = jnp.exp(gf + cache["m"] - m_new)
    i_s = jnp.exp(gi - m_new)
    C = f_s[:, :, None, None] * cache["C"] + i_s[:, :, None, None] * \
        jnp.einsum("bhe,bhf->bhef", kf, vf)
    n = f_s[:, :, None] * cache["n"] + i_s[:, :, None] * kf
    num = jnp.einsum("bhe,bhef->bhf", qf, C)
    den = jnp.abs(jnp.einsum("bhe,bhe->bh", qf, n))
    h = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
    out = _finish(cfg, p, h[:, None].astype(cfg.compute_dtype), z)
    return out, {"C": C, "n": n, "m": m_new, "conv": new_conv}


# ==========================================================================
# sLSTM
# ==========================================================================

def slstm_dims(cfg):
    d = cfg.d_model
    H = cfg.n_heads
    return d, H, d // H


def init_slstm(cfg, key):
    d, H, dh = slstm_dims(cfg)
    kw, kr, kn, kf1, kf2 = jax.random.split(key, 5)
    pd = cfg.params_dtype
    ff = int(cfg.xlstm.proj_factor_slstm * d)
    return {
        "W": common.dense_init(kw, (d, 4, H, dh), d, pd),      # z i f o
        "R": common.dense_init(kr, (4, H, dh, dh), dh, pd),
        "bias": jnp.zeros((4, H, dh), pd)
                 .at[2].set(jnp.linspace(3.0, 6.0, H)[:, None]),
        "head_norm": jnp.ones((H, dh), pd),
        "ffn_gate": common.dense_init(kf1, (d, ff), d, pd),
        "ffn_up": common.dense_init(kf1, (d, ff), d, pd),
        "ffn_down": common.dense_init(kf2, (ff, d), ff, pd),
    }


def empty_slstm_state(cfg, batch):
    d, H, dh = slstm_dims(cfg)
    z = jnp.zeros((batch, H, dh), jnp.float32)
    return {"h": z, "c": z, "n": z,
            "m": jnp.full((batch, H, dh), -1e30, jnp.float32)}


def _slstm_cell(Rf, bias, st, wx):
    """One timestep.  wx: (B,4,H,dh) precomputed W x_t (f32)."""
    rec = jnp.einsum("bhe,ghef->bghf", st["h"], Rf)            # (B,4,H,dh)
    pre = wx + rec + bias[None]
    zt = jnp.tanh(pre[:, 0])
    gi = pre[:, 1]
    gf = jax.nn.log_sigmoid(pre[:, 2])
    ot = jax.nn.sigmoid(pre[:, 3])
    m_new = jnp.maximum(gf + st["m"], gi)
    f_s = jnp.exp(gf + st["m"] - m_new)
    i_s = jnp.exp(gi - m_new)
    c = f_s * st["c"] + i_s * zt
    n = f_s * st["n"] + i_s
    h = ot * c / jnp.maximum(n, 1e-6)
    return {"h": h, "c": c, "n": n, "m": m_new}


def slstm_forward(cfg, p, x, cache=None, return_cache=False):
    """Sequential sLSTM + fused GeGLU FFN.  x: (B, S, d)."""
    d, H, dh = slstm_dims(cfg)
    B, S, _ = x.shape
    wx = jnp.einsum("bsd,dghe->bsghe", x.astype(jnp.float32),
                    p["W"].astype(jnp.float32))
    st0 = cache if cache is not None else empty_slstm_state(cfg, B)
    st0 = {k2: st0[k2] for k2 in ("h", "c", "n", "m")}
    Rf = p["R"].astype(jnp.float32)
    bias = p["bias"].astype(jnp.float32)

    def step(st, wxt):
        st = _slstm_cell(Rf, bias, st, wxt)
        return st, st["h"]

    st_fin, hs = jax.lax.scan(step, st0, wx.swapaxes(0, 1))
    h = hs.swapaxes(0, 1)                                      # (B,S,H,dh)
    out = _slstm_out(cfg, p, x, h)
    if return_cache:
        return out, st_fin
    return out


def slstm_decode(cfg, p, x, cache):
    d, H, dh = slstm_dims(cfg)
    wx = jnp.einsum("bsd,dghe->bsghe", x.astype(jnp.float32),
                    p["W"].astype(jnp.float32))[:, 0]
    st = _slstm_cell(p["R"].astype(jnp.float32),
                     p["bias"].astype(jnp.float32),
                     {k2: cache[k2] for k2 in ("h", "c", "n", "m")}, wx)
    out = _slstm_out(cfg, p, x, st["h"][:, None])
    return out, st


def _slstm_out(cfg, p, x, h):
    """Headwise norm + GeGLU FFN (proj factor 4/3)."""
    dt = cfg.compute_dtype
    d, H, dh = slstm_dims(cfg)
    hf = h.astype(jnp.float32)
    var = jnp.mean(jnp.square(hf), axis=-1, keepdims=True)
    hn = (hf * jax.lax.rsqrt(var + 1e-6)
          * p["head_norm"].astype(jnp.float32)).astype(dt)
    hn = hn.reshape(x.shape[0], h.shape[1], d)
    g = jax.nn.gelu(jnp.einsum("bsd,df->bsf", hn, p["ffn_gate"].astype(dt)))
    u = jnp.einsum("bsd,df->bsf", hn, p["ffn_up"].astype(dt))
    return jnp.einsum("bsf,fd->bsd", g * u, p["ffn_down"].astype(dt))
