"""Mamba mixer in chunked SSD form (TPU-native adaptation).

The CUDA selective-scan kernel streams per-(channel,state) recurrences
through shared memory — a form with no MXU analogue.  We adopt the SSD
(Mamba-2) parameterization: scalar decay per head per step, head dim P,
shared B/C of size N.  The sequence is processed in chunks of length L:

  intra-chunk:  y[s] += sum_{t<=s} (C_s . B_t) * exp(l_s - l_t) * xbar_t
                -> an (L, L) attention-like matmul per head (MXU shaped)
  inter-chunk:  h' = exp(l_L) * h + sum_t exp(l_L - l_t) * B_t xbar_t^T
                y[s] += C_s . (exp(l_s) * h_prev)
                -> a lax.scan over chunks carrying (B, H, N, P) state

where l = cumsum(log a) within the chunk and xbar = x * dt.  Decode keeps the
O(1) recurrent state: h = a*h + B xbar^T.  The Pallas kernel
(kernels/mamba_scan) implements the intra-chunk part; this file is the jnp
reference path lowered by the dry-run.  DESIGN.md records the Mamba-1 ->
SSD parameterization substitution.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import common

HEAD_DIM = 128  # SSD head dim P


def dims(cfg):
    di = cfg.d_inner_mamba
    P = min(HEAD_DIM, di)
    H = di // P
    return di, H, P, cfg.mamba.d_state


def init_mamba(cfg, key):
    d = cfg.d_model
    di, H, P, N = dims(cfg)
    dc = cfg.mamba.d_conv
    kin, kconv, kdt, kB, kC, kout, kA = jax.random.split(key, 7)
    pd = cfg.params_dtype
    # A init: -uniform(1, 16) per head (mamba convention), stored as log(-A)
    a_init = jnp.log(jax.random.uniform(kA, (H,), jnp.float32, 1.0, 16.0))
    return {
        "w_in": common.dense_init(kin, (d, 2 * di), d, pd),     # x | z gate
        "conv": common.dense_init(kconv, (dc, di), dc, pd),     # depthwise
        "w_dt": common.dense_init(kdt, (di, H), di, pd),
        "dt_bias": jnp.zeros((H,), pd),
        "w_B": common.dense_init(kB, (di, N), di, pd),
        "w_C": common.dense_init(kC, (di, N), di, pd),
        "A_log": a_init.astype(pd),
        "D": jnp.ones((H,), pd),
        "w_out": common.dense_init(kout, (di, d), di, pd),
    }


def _depthwise_conv(cfg, w, x, init_state=None):
    """Causal depthwise conv, taps dc.  x: (B, S, di) -> (B, S, di).

    init_state: (B, dc-1, di) trailing inputs from a previous segment.
    Also returns the new trailing state for caching."""
    dc = w.shape[0]
    if init_state is None:
        init_state = jnp.zeros((x.shape[0], dc - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([init_state, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None, :]
              for i in range(dc))
    new_state = xp[:, xp.shape[1] - (dc - 1):]
    return out, new_state


def _proj_scan_inputs(cfg, p, x):
    """x: (B, S, d) post-norm -> (xbar, z, logA*dt, Bm, Cm)."""
    dt_ = cfg.compute_dtype
    di, H, P, N = dims(cfg)
    xz = jnp.einsum("bsd,de->bse", x, p["w_in"].astype(dt_))
    xi, z = jnp.split(xz, 2, axis=-1)
    return xi, z


def _ssm_params(cfg, p, xc):
    """xc: (B, S, di) post-conv+act."""
    dt_ = cfg.compute_dtype
    di, H, P, N = dims(cfg)
    B_, S, _ = xc.shape
    dt_raw = jnp.einsum("bsd,dh->bsh", xc, p["w_dt"].astype(dt_))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))   # (B,S,H) f32
    A = -jnp.exp(p["A_log"].astype(jnp.float32))             # (H,)
    loga = dt * A[None, None, :]                              # log decay <= 0
    Bm = jnp.einsum("bsd,dn->bsn", xc, p["w_B"].astype(dt_))
    Cm = jnp.einsum("bsd,dn->bsn", xc, p["w_C"].astype(dt_))
    xh = xc.reshape(B_, S, H, P)
    xbar = xh * dt[..., None].astype(xc.dtype)                # x * dt
    return xbar, loga, Bm, Cm, xh


def ssd_scan(cfg, xbar, loga, Bm, Cm, h0=None):
    """Chunked SSD scan.

    xbar: (B, S, H, P); loga: (B, S, H) f32; Bm/Cm: (B, S, N).
    Returns y: (B, S, H, P) and final state (B, H, N, P).
    """
    Bsz, S, H, P = xbar.shape
    N = Bm.shape[-1]
    L = min(cfg.mamba.chunk, S)
    nchunks = math.ceil(S / L)
    pad = nchunks * L - S
    if pad:
        xbar = jnp.pad(xbar, ((0, 0), (0, pad), (0, 0), (0, 0)))
        loga = jnp.pad(loga, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))

    def to_chunks(a):
        return a.reshape(Bsz, nchunks, L, *a.shape[2:]).swapaxes(0, 1)

    xc, lc, bc, cc = map(to_chunks, (xbar, loga, Bm, Cm))
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, N, P), jnp.float32)

    def chunk_step(h, xs):
        xb, la, bm, cm = xs          # (B,L,H,P) (B,L,H) (B,L,N) (B,L,N)
        lcum = jnp.cumsum(la, axis=1)  # (B,L,H) inclusive cum. log decay
        # inter: y_inter[s] = C_s . (exp(l_s) * h)
        dh = jnp.exp(lcum)           # decay from chunk start, (B,L,H)
        y_inter = jnp.einsum("bln,bhnp->blhp", cm, h) * dh[..., None]
        # intra: att[s,t] = (C_s.B_t) exp(l_s - l_t) for t <= s
        cb = jnp.einsum("bsn,btn->bst", cm, bm)[:, None]      # (B,1,S,T)
        dec = lcum[:, :, None, :] - lcum[:, None, :, :]       # (B,S,T,H)
        dec = jnp.transpose(dec, (0, 3, 1, 2))                # (B,H,S,T)
        mask = jnp.tril(jnp.ones((xb.shape[1], xb.shape[1]), bool))
        att = jnp.where(mask[None, None], cb * jnp.exp(dec), 0.0)
        y_intra = jnp.einsum("bhst,bthp->bshp",
                             att.astype(xb.dtype), xb)
        # state update: h' = exp(l_L) h + sum_t exp(l_L - l_t) B_t xbar_t^T
        lL = lcum[:, -1]                                       # (B,H)
        w = jnp.exp(lL[:, None] - lcum)                        # (B,L,H)
        hb = jnp.einsum("bln,blhp->bhnp",
                        bm.astype(jnp.float32),
                        (xb.astype(jnp.float32) * w[..., None]))
        h_new = jnp.exp(lL)[:, :, None, None] * h + hb
        y = y_inter.astype(xb.dtype) + y_intra
        return h_new, y

    h_fin, ys = jax.lax.scan(chunk_step, h0, (xc, lc, bc, cc))
    y = ys.swapaxes(0, 1).reshape(Bsz, nchunks * L, H, P)[:, :S]
    return y, h_fin


def mamba_forward(cfg, p, x, cache=None, return_cache: bool = False):
    """Full-sequence mixer.  x: (B, S, d) -> (B, S, d).

    cache (decode/prefill continuation): {"conv": (B, dc-1, di),
    "h": (B, H, N, P)}; returned updated when return_cache.
    """
    dt_ = cfg.compute_dtype
    di, H, P, N = dims(cfg)
    xi, z = _proj_scan_inputs(cfg, p, x)
    conv_state = cache["conv"] if cache is not None else None
    xc, new_conv = _depthwise_conv(cfg, p["conv"].astype(dt_), xi, conv_state)
    xc = jax.nn.silu(xc)
    xbar, loga, Bm, Cm, xh = _ssm_params(cfg, p, xc)
    h0 = cache["h"] if cache is not None else None
    y, h_fin = ssd_scan(cfg, xbar, loga, Bm, Cm, h0)
    y = y + xh * p["D"].astype(dt_)[None, None, :, None]
    y = y.reshape(x.shape[0], x.shape[1], di)
    out = jnp.einsum("bse,ed->bsd", y * jax.nn.silu(z),
                     p["w_out"].astype(dt_))
    if return_cache:
        return out, {"conv": new_conv, "h": h_fin}
    return out


def mamba_decode(cfg, p, x, cache):
    """Single-token decode.  x: (B, 1, d)."""
    dt_ = cfg.compute_dtype
    di, H, P, N = dims(cfg)
    xi, z = _proj_scan_inputs(cfg, p, x)                      # (B,1,di)
    xc, new_conv = _depthwise_conv(cfg, p["conv"].astype(dt_), xi,
                                   cache["conv"])
    xc = jax.nn.silu(xc)
    xbar, loga, Bm, Cm, xh = _ssm_params(cfg, p, xc)
    a = jnp.exp(loga[:, 0])                                   # (B,H)
    h = cache["h"] * a[:, :, None, None] + jnp.einsum(
        "bn,bhp->bhnp", Bm[:, 0].astype(jnp.float32),
        xbar[:, 0].astype(jnp.float32))
    y = jnp.einsum("bn,bhnp->bhp", Cm[:, 0].astype(jnp.float32), h)
    y = y.astype(dt_)[:, None] + xh * p["D"].astype(dt_)[None, None, :, None]
    y = y.reshape(x.shape[0], 1, di)
    out = jnp.einsum("bse,ed->bsd", y * jax.nn.silu(z),
                     p["w_out"].astype(dt_))
    return out, {"conv": new_conv, "h": h}


def init_cache(cfg, batch: int, dtype=None):
    di, H, P, N = dims(cfg)
    dc = cfg.mamba.d_conv
    dt_ = dtype or cfg.compute_dtype
    return {"conv": jnp.zeros((batch, dc - 1, di), dt_),
            "h": jnp.zeros((batch, H, N, P), jnp.float32)}
