"""Mixture-of-Experts FFN with sort-based dispatch.

Design notes (TPU adaptation):
- GShard-style one-hot dispatch einsums inflate HLO FLOPs by O(E*C/d) fake
  work and blow up memory at 32k sequence lengths.  We instead use the
  sort/scatter formulation: flatten (token, k) slots, stable-sort by expert,
  scatter into a dense (E, C, d) buffer (capacity drop = standard), run the
  expert FFNs as one batched einsum on the MXU, gather back, weighted-sum.
  HLO FLOPs then count only *active* expert compute + router, which keeps the
  roofline's MODEL_FLOPS/HLO_FLOPs ratio honest.
- Under EP (experts sharded over the 'model' mesh axis) the scatter/gather
  lower to the expected all-to-all traffic; the (E, C, d) buffer shards on E.
- Shared experts (Moonlight-style) are a plain dense FFN fused alongside.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import common


def init_moe(cfg, key):
    m = cfg.moe
    d, ff, E = cfg.d_model, m.d_ff_expert, m.num_experts
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    pd = cfg.params_dtype
    p = {
        "router": common.dense_init(kr, (d, E), d, pd),
        "w_gate": common.dense_init(kg, (E, d, ff), d, pd),
        "w_up": common.dense_init(ku, (E, d, ff), d, pd),
        "w_down": common.dense_init(kd, (E, ff, d), ff, pd),
    }
    if m.num_shared:
        ksg, ksu, ksd = jax.random.split(ks, 3)
        sff = m.num_shared * ff
        p["shared"] = {
            "w_gate": common.dense_init(ksg, (d, sff), d, pd),
            "w_up": common.dense_init(ksu, (d, sff), d, pd),
            "w_down": common.dense_init(ksd, (sff, d), sff, pd),
        }
    return p


def _n_groups(batch: int, target: int = 0) -> int:
    """Dispatch groups = the mesh's DP extent when divisible (so the
    group dim pins cleanly to ('pod','data')), else the largest
    power-of-two divisor of batch up to 16."""
    if not target:
        from repro.distributed import constraints
        target = constraints.dp_extent() or 16
    return math.gcd(target, batch)


def moe_ffn(cfg, p, x, capacity_factor: Optional[float] = None,
            return_aux: bool = False):
    """x: (B, S, d) -> (B, S, d) [, aux_loss].

    Grouped dispatch (§Perf iteration 4): tokens reshape to (G, T/G, d)
    with G matching the data axis, and the sort/scatter runs PER GROUP
    (vmap).  With G pinned to the DP axes the scatter is device-local;
    the only cross-device movement is the expert-weight contraction
    (E on the model axis), instead of the full-buffer all-reduce GSPMD
    emits for a globally-indexed scatter (measured 1.56e13 B/dev/step on
    dbrx prefill)."""
    from repro.distributed import constraints
    m = cfg.moe
    if capacity_factor is None:
        capacity_factor = m.capacity_factor
    B, S, d = x.shape
    T = B * S
    E, k = m.num_experts, m.top_k
    dt = cfg.compute_dtype
    G = _n_groups(B)
    Tg = T // G
    C = max(1, math.ceil(Tg * k / E * capacity_factor))
    xt = constraints.pin(x.reshape(G, Tg, d), ("batch", None, None))

    def dispatch_group(xg):
        """xg: (Tg, d) -> (buf (E,C,d), routing metadata)."""
        logits = xg.astype(jnp.float32) @ p["router"].astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)              # (Tg, E)
        gate_w, expert_ix = jax.lax.top_k(probs, k)          # (Tg, k)
        gate_w = gate_w / jnp.clip(gate_w.sum(-1, keepdims=True), 1e-9)
        flat_e = expert_ix.reshape(Tg * k)
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        tok_of_slot = order // k
        starts = jnp.searchsorted(sorted_e, jnp.arange(E))
        pos_in_e = jnp.arange(Tg * k) - starts[sorted_e]
        buf = jnp.zeros((E, C, d), dt)
        buf = buf.at[sorted_e, pos_in_e].set(xg[tok_of_slot].astype(dt),
                                             mode="drop")
        frac = jnp.zeros((E,), jnp.float32).at[flat_e].add(1.0) / (Tg * k)
        w_sorted = gate_w.reshape(Tg * k)[order].astype(dt)
        return buf, (sorted_e, pos_in_e, tok_of_slot, w_sorted, frac,
                     probs.mean(0))

    def combine_group(out_buf, meta, xg):
        sorted_e, pos_in_e, tok_of_slot, w_sorted, _, _ = meta
        slot_out = out_buf.at[sorted_e, pos_in_e].get(mode="fill",
                                                      fill_value=0)
        valid = (pos_in_e < C).astype(dt)
        contrib = slot_out * (w_sorted * valid)[:, None]
        return jnp.zeros((Tg, d), dt).at[tok_of_slot].add(contrib)

    buf, meta = jax.vmap(dispatch_group)(xt)
    # expert contraction at top level: E pinned to the model axis so the
    # per-expert matmuls run where the (E-sharded) weights live — without
    # this pin GSPMD all-gathers the expert weights and replicates the
    # expert FLOPs across the TP axis (§Perf iteration 4, dbrx measured
    # 12x model FLOPs).
    buf = constraints.pin(buf, ("batch", "model", None, None))
    act = common.act_fn(cfg.act)
    gg = jnp.einsum("gecd,edf->gecf", buf, p["w_gate"].astype(dt))
    uu = jnp.einsum("gecd,edf->gecf", buf, p["w_up"].astype(dt))
    out_buf = jnp.einsum("gecf,efd->gecd", act(gg) * uu,
                         p["w_down"].astype(dt))
    out_buf = constraints.pin(out_buf, ("batch", None, None, None))
    out = jax.vmap(combine_group)(out_buf, meta, xt)
    frac_tokens, probs_mean = meta[4], meta[5]
    out = constraints.pin(out, ("batch", None, None)).reshape(T, d)
    xt_flat = x.reshape(T, d)

    if m.num_shared:
        act = common.act_fn(cfg.act)
        sp = p["shared"]
        sg = act(xt_flat.astype(dt) @ sp["w_gate"].astype(dt))
        su = xt_flat.astype(dt) @ sp["w_up"].astype(dt)
        out = out + (sg * su) @ sp["w_down"].astype(dt)

    out = out.reshape(B, S, d)
    if not return_aux:
        return out
    aux = E * jnp.sum(frac_tokens.mean(0) * probs_mean.mean(0))
    return out, aux
