"""Shared model primitives: norms, RoPE, activations, initializers.

Pure-function style: params are nested dicts of jnp arrays; every function
takes (params, x, ...) and is shape-polymorphic over batch/sequence.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# Initializers
# --------------------------------------------------------------------------

def dense_init(key, shape, in_axis_size: Optional[int] = None, dtype=jnp.float32):
    """Truncated-normal fan-in init (MaxText-style)."""
    fan_in = in_axis_size if in_axis_size is not None else shape[0]
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------

def init_norm(cfg, d: int):
    if cfg.norm == "ln":
        return {"scale": jnp.ones((d,), cfg.params_dtype),
                "bias": jnp.zeros((d,), cfg.params_dtype)}
    return {"scale": jnp.ones((d,), cfg.params_dtype)}


def apply_norm(cfg, p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if cfg.norm == "ln":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm (llama/gemma style; gemma uses (1+scale) — folded into init)
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float):
    half = d_head // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    if theta <= 0:
        return x
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    ang = ang[..., None, :]                            # (..., S, 1, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int):
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10_000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------------------
# Activations / misc
# --------------------------------------------------------------------------

def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


def softcap(x, cap: Optional[float]):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def take_embedding(emb, tokens, scale: bool):
    x = jnp.take(emb, tokens, axis=0)
    if scale:
        x = x * jnp.asarray(math.sqrt(emb.shape[-1]), x.dtype)
    return x
