"""Training step: mixed-precision loss/grad + AdamW, with optional
microbatch gradient accumulation and int8 gradient compression hooks."""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.lm import lm_loss
from repro.training import optimizer as opt


def make_train_step(cfg, model, adamw: opt.AdamWConfig,
                    microbatches: int = 1, compress_grads=None,
                    block_q: int = 512):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, m).

    batch: {"tokens": (B, S) int32, "labels": (B, S) int32, [aux inputs]}.
    ``compress_grads`` (training/compression.py) is applied to the gradient
    pytree before the optimizer — int8 + error feedback for the DP
    all-reduce path.
    """

    def loss_fn(params, batch):
        aux = {k: v for k, v in batch.items()
               if k not in ("tokens", "labels")}
        return lm_loss(cfg, model, params, batch["tokens"], batch["labels"],
                       aux=aux or None, remat=True, block_q=block_q)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads
        B = batch["tokens"].shape[0]
        assert B % microbatches == 0
        mb = B // microbatches
        split = jax.tree.map(
            lambda x: x.reshape(microbatches, mb, *x.shape[1:]), batch)

        def body(carry, mb_batch):
            acc, loss_acc = carry
            (loss, _), grads = grad_fn(params, mb_batch)
            acc = jax.tree.map(jnp.add, acc, grads)
            return (acc, loss_acc + loss), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        (grads, loss_sum), _ = jax.lax.scan(body, (zeros, 0.0), split)
        grads = jax.tree.map(lambda g: g / microbatches, grads)
        return loss_sum / microbatches, {}, grads

    def train_step(params, opt_state, batch):
        loss, metrics, grads = compute_grads(params, batch)
        if compress_grads is not None:
            grads = compress_grads(grads)
        params, opt_state, om = opt.apply_updates(adamw, params, grads,
                                                  opt_state)
        return params, opt_state, {"loss": loss, **metrics, **om}

    return train_step
