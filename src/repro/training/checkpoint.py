"""Sharded checkpointing: save/restore param+optimizer pytrees with a
msgpack manifest, resharding on restore (elastic restarts).

Layout (multi-host ready — each process writes only its addressable
shards; this container is single-process so shard_0 holds everything):

  <dir>/step_<N>/manifest.msgpack    tree structure, shapes, dtypes
  <dir>/step_<N>/shard_<P>.npz       flat arrays by leaf index
  <dir>/step_<N>/COMMITTED           write-atomicity marker (last)

Restore accepts a *different* mesh/shardings than the save used: arrays are
loaded on host then device_put with the new NamedShardings (elastic
re-mesh, training/fault_tolerance.py).
"""
from __future__ import annotations

import os
import shutil
from pathlib import Path

import jax
import msgpack
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(ckpt_dir, step: int, tree, process_index: int = 0) -> Path:
    d = Path(ckpt_dir) / f"step_{step:08d}"
    tmp = Path(str(d) + ".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, treedef = _flatten(tree)
    arrays = {f"a{i}": np.asarray(x) for i, x in enumerate(leaves)}
    np.savez(tmp / f"shard_{process_index}.npz", **arrays)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "shapes": [list(np.shape(x)) for x in leaves],
        "dtypes": [str(np.asarray(x).dtype) for x in leaves],
    }
    (tmp / "manifest.msgpack").write_bytes(msgpack.packb(manifest))
    (tmp / "COMMITTED").write_text("ok")
    if d.exists():
        shutil.rmtree(d)
    os.rename(tmp, d)
    return d


def latest_step(ckpt_dir) -> int | None:
    d = Path(ckpt_dir)
    if not d.exists():
        return None
    steps = []
    for p in d.iterdir():
        if p.name.startswith("step_") and (p / "COMMITTED").exists():
            steps.append(int(p.name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir, step: int, target_tree, shardings=None):
    """target_tree provides the pytree structure (e.g. eval_shape output);
    shardings (optional pytree of NamedSharding) reshard on load."""
    d = Path(ckpt_dir) / f"step_{step:08d}"
    assert (d / "COMMITTED").exists(), f"no committed checkpoint at {d}"
    manifest = msgpack.unpackb((d / "manifest.msgpack").read_bytes())
    data = np.load(d / "shard_0.npz")
    leaves = [data[f"a{i}"] for i in range(manifest["n_leaves"])]
    _, treedef = _flatten(target_tree)
    tree = jax.tree.unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, manifest["step"]


def prune(ckpt_dir, keep: int = 3):
    d = Path(ckpt_dir)
    if not d.exists():
        return
    steps = sorted(p for p in d.iterdir() if p.name.startswith("step_")
                   and (p / "COMMITTED").exists())
    for p in steps[:-keep]:
        shutil.rmtree(p)
