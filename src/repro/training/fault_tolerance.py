"""Fault tolerance for 1000+-node training: heartbeat failure detection,
checkpoint/restart, elastic re-mesh, straggler mitigation.

The control logic is host-side (exactly as it would be on a real cluster
coordinator); failures/stragglers are injected through a SimulatedCluster
so every policy is unit-testable on one CPU:

  HeartbeatMonitor    declares a host dead after ``timeout`` missed beats
  TrainSupervisor     run loop: step -> periodic checkpoint; on failure,
                      restore latest committed checkpoint (possibly onto a
                      SMALLER data-parallel mesh: elastic), replay
  StragglerPolicy     per-step host timings -> flag hosts slower than
                      kappa x median; persistent stragglers are evicted
                      (checkpoint-restart without them) — the bounded
                      -staleness alternative simply skips their microbatch
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.training import checkpoint as ckpt_lib


# --------------------------------------------------------------------------
# Heartbeats
# --------------------------------------------------------------------------

@dataclass
class HeartbeatMonitor:
    timeout: float = 30.0
    last_seen: dict = field(default_factory=dict)

    def beat(self, host, now: float):
        self.last_seen[host] = now

    def dead_hosts(self, now: float) -> set:
        return {h for h, t in self.last_seen.items()
                if now - t > self.timeout}


# --------------------------------------------------------------------------
# Stragglers
# --------------------------------------------------------------------------

@dataclass
class StragglerPolicy:
    kappa: float = 2.0             # slow if > kappa * median step time
    evict_after: int = 3           # consecutive slow steps before eviction
    _slow_streak: dict = field(default_factory=dict)

    def observe(self, host_times: dict) -> dict:
        """host -> step seconds.  Returns {'slow': set, 'evict': set}."""
        if not host_times:
            return {"slow": set(), "evict": set()}
        ts = sorted(host_times.values())
        med = ts[len(ts) // 2]
        slow = {h for h, t in host_times.items() if t > self.kappa * med}
        evict = set()
        for h in host_times:
            if h in slow:
                self._slow_streak[h] = self._slow_streak.get(h, 0) + 1
                if self._slow_streak[h] >= self.evict_after:
                    evict.add(h)
            else:
                self._slow_streak[h] = 0
        return {"slow": slow, "evict": evict}


# --------------------------------------------------------------------------
# Simulated cluster (for tests/examples on one CPU)
# --------------------------------------------------------------------------

class SimulatedCluster:
    def __init__(self, n_hosts: int, base_step_s: float = 1.0, seed: int = 0):
        import random
        self.n_hosts = n_hosts
        self.alive = set(range(n_hosts))
        self.base = base_step_s
        self.rng = random.Random(seed)
        self.fail_at: dict = {}        # host -> step to fail at
        self.slow_hosts: dict = {}     # host -> multiplier

    def inject_failure(self, host: int, step: int):
        self.fail_at[host] = step

    def inject_straggler(self, host: int, mult: float):
        self.slow_hosts[host] = mult

    def step_times(self, step: int) -> dict:
        for h, s in list(self.fail_at.items()):
            if step >= s and h in self.alive:
                self.alive.discard(h)
        return {h: self.base * self.slow_hosts.get(h, 1.0)
                * (0.95 + 0.1 * self.rng.random())
                for h in self.alive}

    def evict(self, hosts: set):
        self.alive -= hosts


# --------------------------------------------------------------------------
# Supervisor
# --------------------------------------------------------------------------

@dataclass
class SupervisorConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 20
    keep: int = 3
    max_restarts: int = 8
    min_hosts: int = 1


class TrainSupervisor:
    """Drives (step_fn, state) to ``total_steps`` surviving failures.

    step_fn(state, step, n_hosts) -> state  (raises HostFailure on a dead
    host — in production this is the collective timing out).
    save_fn/restore_fn adapt state <-> checkpoint trees."""

    def __init__(self, cfg: SupervisorConfig, cluster: SimulatedCluster,
                 step_fn: Callable, save_tree: Callable,
                 load_tree: Callable,
                 straggler: Optional[StragglerPolicy] = None):
        self.cfg = cfg
        self.cluster = cluster
        self.step_fn = step_fn
        self.save_tree = save_tree
        self.load_tree = load_tree
        self.straggler = straggler or StragglerPolicy()
        self.events: list = []
        self._known_lost: set = set()

    def run(self, state, total_steps: int):
        cfg = self.cfg
        step = 0
        restarts = 0
        while step < total_steps:
            try:
                times = self.cluster.step_times(step)
                if len(times) < cfg.min_hosts:
                    raise RuntimeError("cluster below minimum size")
                lost = (set(range(self.cluster.n_hosts))
                        - self.cluster.alive - self._known_lost)
                if lost:
                    self._known_lost |= lost
                    raise HostFailure(lost)
                verdict = self.straggler.observe(times)
                if verdict["evict"]:
                    self.events.append(("evict", set(verdict["evict"]), step))
                    self.cluster.evict(verdict["evict"])
                    raise HostFailure(verdict["evict"])
                state = self.step_fn(state, step, len(times))
                step += 1
                if step % cfg.ckpt_every == 0:
                    ckpt_lib.save(cfg.ckpt_dir, step, self.save_tree(state))
                    ckpt_lib.prune(cfg.ckpt_dir, cfg.keep)
                    self.events.append(("ckpt", step))
            except HostFailure as e:
                restarts += 1
                self.events.append(("restart", tuple(sorted(e.hosts)), step))
                if restarts > cfg.max_restarts:
                    raise RuntimeError("too many restarts") from e
                last = ckpt_lib.latest_step(cfg.ckpt_dir)
                if last is not None:
                    tree, _ = ckpt_lib.restore(cfg.ckpt_dir, last,
                                               self.save_tree(state))
                    state = self.load_tree(state, tree,
                                           n_hosts=len(self.cluster.alive))
                    step = last
                else:
                    step = 0
                self.events.append(("resume", step,
                                    len(self.cluster.alive)))
        return state, step


class HostFailure(RuntimeError):
    def __init__(self, hosts):
        super().__init__(f"hosts failed: {hosts}")
        self.hosts = set(hosts)
