"""Gradient compression for the DP all-reduce path: int8 quantization with
error feedback (residual carry), plus top-k sparsification.

In a real multi-pod deployment the inter-pod (DCN) all-reduce runs on the
int8 payload (32x less traffic than f32 at equal step count); here the
transform is applied to the gradient pytree inside train_step so its
*numerics* (and the error-feedback convergence behaviour) are exactly what
the cluster would see.  tests/test_compression.py checks the quantization
error bound and that error feedback keeps SGD convergent.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _quantize_int8(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_int8_ef(grads, err_state):
    """int8 + error feedback.  Returns (grads_as_transmitted, new_err)."""
    def one(g, e):
        g = g.astype(jnp.float32) + e
        q, s = _quantize_int8(g)
        deq = _dequantize(q, s)
        return deq, g - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_e = jax.tree.unflatten(treedef, [o[1] for o in out])
    return new_g, new_e


def compress_topk_ef(grads, err_state, frac: float = 0.05):
    """Magnitude top-k sparsification with error feedback."""
    def one(g, e):
        g = g.astype(jnp.float32) + e
        flat = g.reshape(-1)
        k = max(1, int(flat.size * frac))
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
        mask = (jnp.abs(g) >= thresh).astype(jnp.float32)
        sent = g * mask
        return sent, g - sent

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]))


def compression_ratio_int8(params) -> float:
    """Wire-bytes ratio vs f32 all-reduce (scale overhead included)."""
    total_f32 = sum(4 * p.size for p in jax.tree.leaves(params))
    total_int8 = sum(p.size + 4 for p in jax.tree.leaves(params))
    return total_f32 / total_int8
