"""Gradient compression for the DP all-reduce path: int8 quantization with
error feedback (residual carry), plus top-k sparsification — and the KV
page wire codec the overlay's cross-node page migration rides on.

In a real multi-pod deployment the inter-pod (DCN) all-reduce runs on the
int8 payload (32x less traffic than f32 at equal step count); here the
transform is applied to the gradient pytree inside train_step so its
*numerics* (and the error-feedback convergence behaviour) are exactly what
the cluster would see.  tests/test_compression.py checks the quantization
error bound and that error feedback keeps SGD convergent.

``compress_kv_blocks``/``decompress_kv_blocks`` serialize a gathered
(R, n_pages, BLOCK, nkv, h) K/V slab for the ``kv_pages`` overlay message
(serving/engine.export_pages -> import_pages): ``raw`` ships the arena
dtype losslessly, ``fp16`` halves f32 wire bytes, ``int8`` quantizes with
a per-(repeat, page) scale — the same max-abs scheme as the gradient path,
minus error feedback (pages are shipped once, there is no residual to
carry).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _quantize_int8(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_int8_ef(grads, err_state):
    """int8 + error feedback.  Returns (grads_as_transmitted, new_err)."""
    def one(g, e):
        g = g.astype(jnp.float32) + e
        q, s = _quantize_int8(g)
        deq = _dequantize(q, s)
        return deq, g - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_e = jax.tree.unflatten(treedef, [o[1] for o in out])
    return new_g, new_e


def compress_topk_ef(grads, err_state, frac: float = 0.05):
    """Magnitude top-k sparsification with error feedback."""
    def one(g, e):
        g = g.astype(jnp.float32) + e
        flat = g.reshape(-1)
        k = max(1, int(flat.size * frac))
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
        mask = (jnp.abs(g) >= thresh).astype(jnp.float32)
        sent = g * mask
        return sent, g - sent

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]))


def _np_dtype(name: str) -> np.dtype:
    """np.dtype by name, reaching into ml_dtypes for the jax-only floats
    (bfloat16 arenas serialize through their ml_dtypes view)."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def compress_kv_blocks(blocks, mode: str = "fp16") -> dict:
    """(R, n_pages, BLOCK, nkv, h) K/V slab -> msgpack-able wire record.

    ``raw`` is lossless (arena dtype bytes as-is); ``fp16`` casts float32
    arenas down for half the wire bytes; ``int8`` quantizes with one
    max-abs scale per (repeat, page) so a hot page with outliers never
    flattens its neighbours' resolution."""
    arr = np.asarray(jax.device_get(blocks))
    rec = {"mode": mode, "dtype": str(arr.dtype), "shape": list(arr.shape)}
    if mode == "raw":
        rec["data"] = arr.tobytes()
    elif mode == "fp16":
        rec["data"] = arr.astype(np.float16).tobytes()
    elif mode == "int8":
        flat = arr.astype(np.float32).reshape(arr.shape[0], arr.shape[1], -1)
        scale = np.maximum(np.abs(flat).max(axis=-1), 1e-12) / 127.0
        q = np.clip(np.round(flat / scale[..., None]), -127, 127)
        rec["data"] = q.astype(np.int8).tobytes()
        rec["scale"] = scale.astype(np.float32).tobytes()
    else:
        raise ValueError(f"unknown KV wire mode {mode!r}")
    return rec


def decompress_kv_blocks(rec: dict, dtype=None):
    """Wire record -> (R, n_pages, BLOCK, nkv, h) ndarray in ``dtype``
    (defaults to the source arena dtype recorded at compression)."""
    shape = tuple(int(s) for s in rec["shape"])
    out_dtype = _np_dtype(str(dtype)) if dtype is not None \
        else _np_dtype(rec["dtype"])
    mode = rec["mode"]
    if mode == "raw":
        arr = np.frombuffer(rec["data"], _np_dtype(rec["dtype"]))
        arr = arr.reshape(shape)
    elif mode == "fp16":
        arr = np.frombuffer(rec["data"], np.float16).reshape(shape)
    elif mode == "int8":
        q = np.frombuffer(rec["data"], np.int8)
        q = q.reshape(shape[0], shape[1], -1).astype(np.float32)
        scale = np.frombuffer(rec["scale"], np.float32)
        scale = scale.reshape(shape[0], shape[1])
        arr = (q * scale[..., None]).reshape(shape)
    else:
        raise ValueError(f"unknown KV wire mode {mode!r}")
    return np.asarray(arr, out_dtype)


def compression_ratio_int8(params) -> float:
    """Wire-bytes ratio vs f32 all-reduce (scale overhead included)."""
    total_f32 = sum(4 * p.size for p in jax.tree.leaves(params))
    total_int8 = sum(p.size + 4 for p in jax.tree.leaves(params))
    return total_f32 / total_int8
