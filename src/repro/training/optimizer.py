"""AdamW + schedules, pure JAX (no optax dependency — substrate is built here).

State is a pytree mirroring params (mu, nu in f32), ZeRO-sharded with the
same PartitionSpecs as the corresponding parameters.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_state(params):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"mu": zeros, "nu": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(cfg: AdamWConfig, params, grads, state):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * clip
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mhat = mu / b1c
        nhat = nu / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in
           zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
