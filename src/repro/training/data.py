"""Data pipelines: synthetic LM corpora (training) + the paper's serving
workload generators (§5.1), matched on published statistics.

Training corpus: a mixture of order-2 Markov chains over the vocab — cheap
to sample, learnable by tiny models (the verification benches need a GT
model that is *meaningfully better* than truncated/quantized impostors).

Serving workloads (dataset stand-ins, see DESIGN.md substitutions):
  ToolUse  — Zipf-1.1 over shared tool-instruction prefixes, ~7.2k-token
             prompts, 100-token outputs
  Coding   — Zipf-0.8, ~1.8k-token prompts, minimal prefix overlap,
             1000-token outputs
  LongQA   — Zipf-0.6 over long documents (~11k tokens), 100-token outputs
  Mixed    — 3:6:1 blend (ToolUse:Coding:LongQA), per the paper
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


# --------------------------------------------------------------------------
# Training corpus
# --------------------------------------------------------------------------

class MarkovCorpus:
    """Order-1 Markov chain with sparse transitions (structured synthetic).

    Entropy floor ~= ln(branching) + noise*ln(vocab): branching=2/noise=0.02
    gives PPL ~2 for a converged model — low enough that greedy responses
    score high normalized perplexity (the Fig 11 regime)."""

    def __init__(self, vocab: int, seed: int = 0, branching: int = 4,
                 noise: float = 0.1):
        self.vocab = vocab
        self.rng = np.random.default_rng(seed)
        self.branching = branching
        self.noise = noise
        self._next = self.rng.integers(
            0, vocab, size=(vocab, branching)).astype(np.int32)

    def sample(self, batch: int, seq_len: int,
               rng: Optional[np.random.Generator] = None) -> np.ndarray:
        rng = rng or self.rng
        out = np.empty((batch, seq_len + 1), np.int32)
        cur = rng.integers(0, self.vocab, size=batch)
        for t in range(seq_len + 1):
            out[:, t] = cur
            pick = rng.integers(0, self.branching, size=batch)
            nxt = self._next[cur, pick]
            noisy = rng.random(batch) < self.noise
            nxt = np.where(noisy, rng.integers(0, self.vocab, batch), nxt)
            cur = nxt
        return out

    def batches(self, batch: int, seq_len: int, steps: int,
                seed: int = 1) -> Iterator[dict]:
        rng = np.random.default_rng(seed)
        for _ in range(steps):
            seqs = self.sample(batch, seq_len, rng)
            yield {"tokens": seqs[:, :-1], "labels": seqs[:, 1:]}


# --------------------------------------------------------------------------
# Serving workloads
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class WorkloadSpec:
    name: str
    n_prefixes: int          # library of shared prefixes (tools / docs)
    zipf_a: float            # zipf exponent for prefix popularity
    prefix_len_mean: int
    suffix_len_mean: int
    output_cap: int


TOOLUSE = WorkloadSpec("ToolUse", 64, 1.1, 6400, 800, 100)
CODING = WorkloadSpec("Coding", 512, 0.8, 200, 1600, 1000)
LONGQA = WorkloadSpec("LongQA", 32, 0.6, 10400, 600, 100)


def _zipf_choice(rng, n: int, a: float) -> int:
    w = 1.0 / np.power(np.arange(1, n + 1), a)
    w /= w.sum()
    return int(rng.choice(n, p=w))


@dataclass
class Query:
    tokens: list
    prefix_id: int
    workload: str
    max_new: int
    session: Optional[str] = None


class WorkloadGen:
    def __init__(self, spec: WorkloadSpec, vocab: int = 32_000,
                 seed: int = 0, scale: float = 1.0):
        """scale < 1 shrinks token counts for real-engine (CPU) runs."""
        self.spec = spec
        self.vocab = vocab
        self.rng = np.random.default_rng(seed)
        self.scale = scale
        base = np.random.default_rng(seed + 1)
        self._prefixes = []
        for i in range(spec.n_prefixes):
            ln = max(8, int(base.normal(spec.prefix_len_mean,
                                        spec.prefix_len_mean * 0.2) * scale))
            self._prefixes.append(
                base.integers(2, vocab, size=ln).astype(int).tolist())

    def sample(self) -> Query:
        s = self.spec
        pid = _zipf_choice(self.rng, s.n_prefixes, s.zipf_a)
        sl = max(4, int(self.rng.normal(s.suffix_len_mean,
                                        s.suffix_len_mean * 0.3) * self.scale))
        suffix = self.rng.integers(2, self.vocab, size=sl).astype(int).tolist()
        out_cap = max(4, int(s.output_cap * min(self.scale * 4, 1.0)))
        return Query(self._prefixes[pid] + suffix, pid, s.name, out_cap)


class MixedWorkload:
    """ToolUse : Coding : LongQA = 3 : 6 : 1 (paper §5.1)."""

    def __init__(self, vocab: int = 32_000, seed: int = 0,
                 scale: float = 1.0):
        self.gens = [WorkloadGen(TOOLUSE, vocab, seed, scale),
                     WorkloadGen(CODING, vocab, seed + 1, scale),
                     WorkloadGen(LONGQA, vocab, seed + 2, scale)]
        self.weights = np.array([3, 6, 1], float)
        self.weights /= self.weights.sum()
        self.rng = np.random.default_rng(seed + 3)

    def sample(self) -> Query:
        g = self.gens[int(self.rng.choice(3, p=self.weights))]
        return g.sample()


def poisson_arrivals(rate_per_s: float, n: int, seed: int = 0,
                     t0: float = 0.0) -> list[float]:
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_per_s, size=n)
    return (t0 + np.cumsum(gaps)).tolist()
