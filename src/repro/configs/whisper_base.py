"""whisper-base [audio] — enc-dec, conv frontend (stub) [arXiv:2212.04356; unverified].

6L d_model=512 8H (kv=8) d_ff=2048 vocab=51865.  Interpreted as the
whisper-base 6-layer encoder + 6-layer decoder (the published whisper-base).
The conv1d mel frontend is a STUB: ``input_specs`` supplies precomputed
frame embeddings (B, frames, d_model) with frames = seq_len // 2 (the conv
stack's 2x downsampling).  Decoder: causal self-attn + cross-attn to the
encoder output.  LayerNorm + plain GELU FFN (no GLU), learned positions.
long_500k SKIPPED (full attention).
"""
from repro.configs.base import ArchConfig, LayerSpec, register

# one whisper decoder layer == self-attn + cross-attn + a single FFN
_pattern = (LayerSpec(mixer="attn", ffn="none"),
            LayerSpec(mixer="cross_attn", ffn="dense"))

CONFIG = register(ArchConfig(
    name="whisper-base",
    family="audio",
    n_layers=12,            # decoder: 6 x (self-attn + cross-attn) positions
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=51865,
    pattern=_pattern,
    is_encdec=True,
    n_enc_layers=6,
    act="gelu",
    glu=False,
    norm="ln",
    rope_theta=0.0,         # 0 => learned/sinusoidal absolute positions
    tie_embeddings=True,
    source="arXiv:2212.04356; unverified",
))
