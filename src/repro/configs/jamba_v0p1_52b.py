"""jamba-v0.1-52b [hybrid] — Mamba+attn 1:7 interleave, MoE [arXiv:2403.19887; hf].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, MoE 16e top-2.
Repeating 8-layer block: attention at position 4, Mamba elsewhere (1:7);
MoE on odd positions, dense FFN on even (every-other-layer MoE, as in the
Jamba paper).  Mamba: d_state=16, d_conv=4, expand=2.
Hybrid ⇒ long_500k RUNS (4 full-attn layers of 32; KV for those shards
over the data axis — context parallelism).
"""
from repro.configs.base import ArchConfig, LayerSpec, MambaSpec, MoESpec, register


def _pos(i: int) -> LayerSpec:
    mixer = "attn" if i == 4 else "mamba"
    ffn = "moe" if i % 2 == 1 else "dense"
    return LayerSpec(mixer=mixer, ffn=ffn)


_pattern = tuple(_pos(i) for i in range(8))

CONFIG = register(ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65536,
    pattern=_pattern,
    moe=MoESpec(num_experts=16, top_k=2, d_ff_expert=14336, num_shared=0),
    mamba=MambaSpec(d_state=16, d_conv=4, expand=2, chunk=128),
    long_context_ok=True,   # hybrid: 4 attn layers' KV shards over 'data'
    rope_theta=10_000.0,
    source="arXiv:2403.19887; hf",
))
