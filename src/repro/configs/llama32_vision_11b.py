"""llama-3.2-vision-11b [vlm] — cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision; unverified].

40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.  Pattern of 5:
four self-attention layers + one image cross-attention layer (8 cross
layers across 40).  The vision frontend is a STUB: ``input_specs``
supplies precomputed patch embeddings (B, 1600, d_model).
long_500k SKIPPED (full attention).
"""
from repro.configs.base import ArchConfig, LayerSpec, register

_pattern = tuple([LayerSpec(mixer="attn")] * 4 +
                 [LayerSpec(mixer="cross_attn")])

CONFIG = register(ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=128256,
    pattern=_pattern,
    rope_theta=500_000.0,
    n_image_tokens=1600,
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
))
