"""moonshot-v1-16b-a3b [moe] — kimi/moonlight, 64e top-6
[hf:moonshotai/Moonlight-16B-A3B; hf].

48L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=163840, MoE 64 experts
top-6, fine-grained experts (d_ff_expert=1408) + 2 shared experts
(Moonlight/DeepSeek-V3 style).  long_500k SKIPPED (full attention).
"""
from repro.configs.base import ArchConfig, LayerSpec, MoESpec, register

CONFIG = register(ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=163840,
    pattern=(LayerSpec(mixer="attn", ffn="moe"),),
    moe=MoESpec(num_experts=64, top_k=6, d_ff_expert=1408, num_shared=2),
    rope_theta=50_000.0,
    source="hf:moonshotai/Moonlight-16B-A3B; hf",
))
