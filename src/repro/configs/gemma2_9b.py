"""gemma2-9b [dense] — local+global alternating, logit softcap [arXiv:2408.00118; hf].

42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000.  Alternating
local(4096-window)/global attention, attn softcap 50, final softcap 30,
GeGLU, tied embeddings scaled by sqrt(d_model), double (sandwich) norms.
long_500k SKIPPED: global layers are full attention.
"""
from repro.configs.base import ArchConfig, LayerSpec, register

_pattern = (LayerSpec(mixer="attn", window=4096, ffn="dense"),
            LayerSpec(mixer="attn", window=None, ffn="dense"))

CONFIG = register(ArchConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    d_head=256,
    d_ff=14336,
    vocab=256_000,
    pattern=_pattern,
    rope_theta=10_000.0,
    attn_softcap=50.0,
    final_softcap=30.0,
    act="gelu",
    glu=True,
    tie_embeddings=True,
    embed_scale=True,
    double_norm=True,
    source="arXiv:2408.00118; hf",
))
