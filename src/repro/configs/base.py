"""Architecture & shape configuration system.

Every assigned architecture is a frozen ``ArchConfig`` built from a repeating
``pattern`` of ``LayerSpec`` positions (scan-over-repeats keeps the HLO
compact for the 512-device dry-run).  ``reduced()`` returns a tiny same-family
config for CPU smoke tests.  ``input_specs()`` produces ShapeDtypeStruct
stand-ins for every model input of a given (config, shape) cell — no device
allocation, weak-type-correct, shardable.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

# --------------------------------------------------------------------------
# Layer / block specs
# --------------------------------------------------------------------------

MIXERS = ("attn", "cross_attn", "mamba", "mlstm", "slstm")
FFNS = ("dense", "moe", "none")


@dataclass(frozen=True)
class LayerSpec:
    """One position inside the repeating block pattern."""

    mixer: str = "attn"           # attn | cross_attn | mamba | mlstm | slstm
    window: Optional[int] = None  # sliding-window size for local attention
    ffn: str = "dense"            # dense | moe | none

    def __post_init__(self):
        assert self.mixer in MIXERS, self.mixer
        assert self.ffn in FFNS, self.ffn


@dataclass(frozen=True)
class MoESpec:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0           # shared (always-on) experts, Moonlight-style
    capacity_factor: float = 1.25  # E/k => lossless (no token drops)


@dataclass(frozen=True)
class MambaSpec:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    chunk: int = 128              # SSD chunk length (TPU-native form)


@dataclass(frozen=True)
class XLSTMSpec:
    proj_factor_mlstm: float = 2.0
    proj_factor_slstm: float = 4.0 / 3.0
    conv_width: int = 4


# --------------------------------------------------------------------------
# Architecture config
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
    d_head: Optional[int] = None  # default d_model // n_heads

    # attention options
    rope_theta: float = 10_000.0
    attn_softcap: Optional[float] = None    # gemma2: 50.0
    final_softcap: Optional[float] = None   # gemma2: 30.0
    attn_scale: Optional[float] = None      # override 1/sqrt(d_head)
    double_norm: bool = False               # gemma2 post-norms
    # zero-pad query heads per GQA group at compute time so the head dim
    # shards under TP (yi-34b: 56 -> 64).  Padded heads have zero output
    # projection — mathematically exact, ~n_pad/n_heads extra attention
    # FLOPs, 16x less replication.  §Perf iteration 3.
    head_pad: int = 0

    # ffn / embedding options
    act: str = "silu"             # silu (SwiGLU) | gelu (GeGLU or plain)
    glu: bool = True              # gated linear unit FFN
    norm: str = "rms"             # rms | ln
    tie_embeddings: bool = False
    embed_scale: bool = False     # gemma: scale embeddings by sqrt(d_model)

    moe: Optional[MoESpec] = None
    mamba: Optional[MambaSpec] = None
    xlstm: Optional[XLSTMSpec] = None

    # modality / enc-dec extras
    is_encdec: bool = False
    n_enc_layers: int = 0
    n_image_tokens: int = 0       # vlm cross-attention memory length (stub frontend)

    # long-context policy: None = derive (every mixer sub-quadratic);
    # hybrids override to True (their few full-attn layers shard KV over
    # the data axis — context parallelism)
    long_context_ok: Optional[bool] = None

    # numerics
    dtype: str = "bfloat16"       # activation/compute dtype
    param_dtype: str = "float32"  # master/storage dtype (serve path casts to dtype)

    # kernels (TPU only; dry-run lowers the jnp reference path)
    use_kernels: bool = False

    # speculative decode (serving-time policy, not an architecture trait:
    # no effect on params/init).  With ``spec_enabled`` a paged scheduler
    # verifies up to ``spec_k`` self-drafted n-gram tokens per slot per
    # round in one multi-token dispatch (models/lm.verify_paged); outputs
    # stay token-identical to greedy non-speculative decoding.
    spec_enabled: bool = False
    spec_k: int = 4

    source: str = ""              # provenance note from the assignment brief

    # ---- derived ---------------------------------------------------------
    def __post_init__(self):
        assert self.n_layers % len(self.pattern) == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"pattern length {len(self.pattern)}")
        if self.d_head is None:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)

    @property
    def n_repeats(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def padded_vocab(self) -> int:
        """Embedding/lm-head table size: vocab rounded up to a multiple of
        128 so the vocab dim shards under TP (whisper's 51865 -> 51968;
        all other assigned vocabs are already 128-aligned).  Logits beyond
        ``vocab`` are masked to -inf — outputs are exactly equivalent."""
        return (self.vocab + 127) // 128 * 128

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def params_dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def d_inner_mamba(self) -> int:
        return self.mamba.expand * self.d_model if self.mamba else 0

    @property
    def supports_long_context(self) -> bool:
        """True iff decode state is sub-quadratic (O(1)/O(window) mixers),
        or the config explicitly opts in (hybrids: sparse full-attn layers
        with context-parallel KV)."""
        if self.long_context_ok is not None:
            return self.long_context_ok
        for spec in self.pattern:
            if spec.mixer == "attn" and spec.window is None:
                return False
        return True

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs are (or contain) decoders

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dict(
            n_layers=len(self.pattern),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_head=16,
            d_ff=128 if self.d_ff else 0,
            vocab=512,
            n_enc_layers=min(self.n_enc_layers, len(self.pattern)) if self.is_encdec else 0,
            n_image_tokens=16 if self.n_image_tokens else 0,
            dtype="float32",
            param_dtype="float32",
        )
        pattern = tuple(
            replace(s, window=8 if s.window is not None else None)
            for s in self.pattern)
        kw["pattern"] = pattern
        if self.moe:
            # lossless capacity so smoke tests are exactly reproducible
            kw["moe"] = MoESpec(num_experts=4, top_k=2, d_ff_expert=64,
                                num_shared=min(self.moe.num_shared, 1),
                                capacity_factor=2.0)
        if self.mamba:
            kw["mamba"] = MambaSpec(d_state=8, d_conv=4, expand=2, chunk=16)
        if self.xlstm:
            kw["xlstm"] = self.xlstm
        return replace(self, name=self.name + "-reduced", **kw)

    # Parameter count (dense + embeddings + experts), for MODEL_FLOPS.
    def param_counts(self) -> dict:
        d, dh = self.d_model, self.d_head
        nq, nkv = self.n_heads, self.n_kv_heads
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        per_pos_total = {}
        per_pos_active = {}
        for i, spec in enumerate(self.pattern):
            p = 0
            if spec.mixer in ("attn", "cross_attn"):
                p += d * nq * dh + 2 * d * nkv * dh + nq * dh * d
            elif spec.mixer == "mamba":
                di, ds = self.d_inner_mamba, self.mamba.d_state
                p += d * 2 * di + di * self.mamba.d_conv + di * 2 * ds
                p += di * ds + di + di * d  # dt/B/C proj + A + out
            elif spec.mixer == "mlstm":
                di = int(self.xlstm.proj_factor_mlstm * d)
                p += d * 2 * di + 2 * di * di + 2 * di + di * d
            elif spec.mixer == "slstm":
                nh = self.n_heads
                hdim = d // nh
                p += 4 * d * d + 4 * nh * hdim * hdim  # W gates + blockdiag R
                ff = int(self.xlstm.proj_factor_slstm * d)
                p += 3 * d * ff
            a = p
            if spec.ffn == "dense" and self.d_ff:
                ff = (3 if self.glu else 2) * d * self.d_ff
                p += ff
                a += ff
            elif spec.ffn == "moe":
                m = self.moe
                per_e = 3 * d * m.d_ff_expert
                p += m.num_experts * per_e + d * m.num_experts
                a += (m.top_k + m.num_shared) * per_e + d * m.num_experts
                p += m.num_shared * per_e
            per_pos_total[i] = p
            per_pos_active[i] = a
        total = emb + self.n_repeats * sum(per_pos_total.values())
        active = emb + self.n_repeats * sum(per_pos_active.values())
        if self.is_encdec:
            enc = self.n_enc_layers * (d * nq * dh * 2 + 2 * d * nkv * dh +
                                       (3 if self.glu else 2) * d * self.d_ff)
            total += enc
            active += enc
        return {"total": total, "active": active}


# --------------------------------------------------------------------------
# Shapes (assigned input-shape set for LM-family transformers)
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def cell_is_runnable(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether an (arch x shape) cell applies; reason when it does not."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("full quadratic attention: 500k-token decode state is "
                       "O(seq) KV with O(seq) attention per token — skipped "
                       "per the brief (not sub-quadratic)")
    return True, ""


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train:   tokens/labels (B, S) int32   [+ frames/image embeddings]
    prefill: tokens (B, S) int32          [+ aux]
    decode:  token (B, 1) int32, pos (B,) int32 — the KV cache itself is part
             of the step signature and is built by ``models.lm.cache_specs``.
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    cd = cfg.compute_dtype
    specs = {}
    if shape.kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
    elif shape.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
    else:  # decode: one new token against a cache of length S
        specs["tokens"] = jax.ShapeDtypeStruct((B, 1), i32)
        specs["pos"] = jax.ShapeDtypeStruct((B,), i32)
    if cfg.is_encdec:
        # STUB modality frontend: precomputed conv frame embeddings.
        T = S // 2 if shape.kind != "decode" else cfg_enc_frames(cfg, S)
        if shape.kind == "decode":
            pass  # encoder output lives in the cross-KV cache
        else:
            specs["frames"] = jax.ShapeDtypeStruct((B, T, cfg.d_model), cd)
    if cfg.n_image_tokens:
        if shape.kind != "decode":
            specs["image_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_image_tokens, cfg.d_model), cd)
    return specs


def cfg_enc_frames(cfg: ArchConfig, seq_len: int) -> int:
    return seq_len // 2


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

_REGISTRY: dict = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if not _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


_CONFIG_MODULES = [
    "xlstm_1p3b", "gemma2_9b", "yi_34b", "h2o_danube_1p8b", "granite_20b",
    "llama32_vision_11b", "moonshot_v1_16b_a3b", "dbrx_132b", "jamba_v0p1_52b",
    "whisper_base", "gentorrent_llama3_8b",
]


def _load_all():
    import importlib
    for m in _CONFIG_MODULES:
        importlib.import_module(f"repro.configs.{m}")


ASSIGNED = [
    "xlstm-1.3b", "gemma2-9b", "yi-34b", "h2o-danube-1.8b", "granite-20b",
    "llama-3.2-vision-11b", "moonshot-v1-16b-a3b", "dbrx-132b",
    "jamba-v0.1-52b", "whisper-base",
]
