"""yi-34b [dense] — llama-arch GQA [arXiv:2403.04652; hf].

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
long_500k SKIPPED (full attention).
"""
from repro.configs.base import ArchConfig, LayerSpec, register

CONFIG = register(ArchConfig(
    name="yi-34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64_000,
    pattern=(LayerSpec(mixer="attn"),),
    rope_theta=5_000_000.0,
    head_pad=8,   # 56 -> 64 padded heads: shardable by the 16-way TP axis
    source="arXiv:2403.04652; hf",
))
