"""h2o-danube-1.8b [dense] — llama+mistral mix, SWA [arXiv:2401.16818; hf].

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000.  Sliding-window
attention (mistral-style, window 4096) on all layers ⇒ ring-buffer KV ⇒
long_500k RUNS with O(window) decode state.
"""
from repro.configs.base import ArchConfig, LayerSpec, register

CONFIG = register(ArchConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6912,
    vocab=32_000,
    pattern=(LayerSpec(mixer="attn", window=4096),),
    rope_theta=10_000.0,
    source="arXiv:2401.16818; hf",
))
