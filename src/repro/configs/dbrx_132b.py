"""dbrx-132b [moe] — 16 experts top-4, fine-grained [hf:databricks/dbrx-base; unverified].

40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352, MoE 16e top-4.
long_500k SKIPPED (full attention).
"""
from repro.configs.base import ArchConfig, LayerSpec, MoESpec, register

CONFIG = register(ArchConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab=100352,
    pattern=(LayerSpec(mixer="attn", ffn="moe"),),
    moe=MoESpec(num_experts=16, top_k=4, d_ff_expert=10752, num_shared=0),
    rope_theta=500_000.0,
    source="hf:databricks/dbrx-base; unverified",
))
