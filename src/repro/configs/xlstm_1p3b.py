"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

48L d_model=2048 4H (GQA kv=4) d_ff=0 vocab=50304.  d_ff=0 ⇒ xLSTM-style
blocks with internal up/down projections, no separate FFN.  Ratio 7:1
mLSTM:sLSTM (xLSTM[7:1]): repeating 8-layer block with sLSTM at position 7.
Recurrent state ⇒ long_500k runs with O(1) decode state.
"""
from repro.configs.base import ArchConfig, LayerSpec, XLSTMSpec, register

_pattern = tuple([LayerSpec(mixer="mlstm", ffn="none")] * 7 +
                 [LayerSpec(mixer="slstm", ffn="none")])

CONFIG = register(ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    pattern=_pattern,
    xlstm=XLSTMSpec(proj_factor_mlstm=2.0, proj_factor_slstm=4.0 / 3.0,
                    conv_width=4),
    tie_embeddings=True,
    source="arXiv:2405.04517; unverified",
))
