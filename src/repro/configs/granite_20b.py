"""granite-20b [dense] — llama-arch, code [arXiv:2405.04324; hf].

52L d_model=6144 48H (GQA kv=1, i.e. MQA) d_ff=24576 vocab=49152.
long_500k SKIPPED (full attention).  Under TP the single KV head is
replicated across the model axis (see distributed/sharding.py).
"""
from repro.configs.base import ArchConfig, LayerSpec, register

CONFIG = register(ArchConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab=49152,
    pattern=(LayerSpec(mixer="attn"),),
    rope_theta=10_000.0,
    source="arXiv:2405.04324; hf",
))
