"""The paper's own testbed model: Meta-Llama-3-8B (GenTorrent §5.1).

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.  Used by the
serving benchmarks (as the reduced-config engine model) and as an extra
dry-run subject.
"""
from repro.configs.base import ArchConfig, LayerSpec, register

CONFIG = register(ArchConfig(
    name="gentorrent-llama3-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=128256,
    pattern=(LayerSpec(mixer="attn"),),
    rope_theta=500_000.0,
    source="paper §5.1 testbed (Meta-Llama-3-8B)",
))
