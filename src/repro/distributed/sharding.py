"""Logical-axis -> PartitionSpec rules for every parameter / cache / input.

Baseline scheme (see DESIGN.md §5 and the hillclimb log in EXPERIMENTS.md):
  - batch over ('pod','data')                    [DP; FSDP weights on 'data']
  - heads / d_ff / experts / vocab over 'model'  [TP / EP]
  - KV heads over 'model' only when divisible (GQA kv < mesh would force
    GSPMD padding; otherwise replicate KV, shard Q heads)
  - train: weights & optimizer state FSDP-sharded on 'data' (ZeRO)
  - serve: weights sharded on 'model' only (replicated over 'data')
  - long-context decode (batch=1): cache *sequence* shards over 'data'
    (context parallelism), heads over 'model'

Rules are name-based over the param pytree paths, which keeps them
readable and auditable — the dry-run fails loudly if a leaf is missed.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


def _axis(mesh, name) -> Optional[str]:
    return name if name in mesh.axis_names else None


def _divisible(n: int, mesh, axis: Optional[str]) -> Optional[str]:
    if axis is None:
        return None
    return axis if n % mesh.shape[axis] == 0 else None


def kv_axis(cfg, mesh) -> Optional[str]:
    return _divisible(cfg.n_kv_heads, mesh, _axis(mesh, "model"))


def head_axis(cfg, mesh) -> Optional[str]:
    # GSPMD pads non-divisible head counts (yi: 56 -> 64); acceptable at
    # baseline, revisited in the perf log.
    return _axis(mesh, "model")


def batch_axes(mesh, batch: int):
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    if axes and batch % n == 0:
        return axes
    # fall back to whatever prefix divides
    if "data" in mesh.axis_names and batch % mesh.shape["data"] == 0:
        return ("data",)
    return ()


# --------------------------------------------------------------------------
# Parameter specs
# --------------------------------------------------------------------------

def param_pspec(cfg, path: tuple, shape: tuple, mesh, train: bool) -> P:
    """PartitionSpec for one parameter leaf, identified by its tree path.

    Every rule is divisibility-guarded: jax.jit's explicit in_shardings
    reject non-divisible dims (no GSPMD padding for inputs), so e.g. yi-34b's
    56 query heads stay unsharded at baseline (d_ff carries the TP)."""
    names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    leaf = names[-1]
    stacked = ("blocks" in names) or ("layers" in names)
    if stacked:  # scanned-layer stack: leading repeat dim, never sharded
        shape = shape[1:]
    fsdp = _axis(mesh, "data") if train else None
    mdl = _axis(mesh, "model")
    kva = kv_axis(cfg, mesh)

    def fs(dim: int):  # fsdp only if divisible
        return _divisible(dim, mesh, fsdp)

    def md(dim: int):
        return _divisible(dim, mesh, mdl)

    def rule() -> tuple:
        if leaf in ("embed", "lm_head", "pos_embed"):
            return (md(shape[0]), fs(shape[1]))
        if leaf in ("scale",) or (leaf == "bias" and len(shape) == 1):
            return (None,)
        if leaf == "wq":
            return (fs(shape[0]), md(shape[1]), None)
        if leaf in ("wk", "wv"):
            return (fs(shape[0]), kva, None)
        if leaf == "wo":
            return (md(shape[0]), None, fs(shape[2]))
        if leaf in ("w_gate", "w_up", "w_in", "ffn_gate", "ffn_up"):
            if len(shape) == 3:   # MoE experts (E, d, ff)
                return (md(shape[0]), fs(shape[1]), None)
            return (fs(shape[0]), md(shape[1]))
        if leaf in ("w_down", "w_out", "ffn_down"):
            if len(shape) == 3:   # (E, ff, d)
                return (md(shape[0]), None, fs(shape[2]))
            return (md(shape[0]), fs(shape[1]))
        if leaf == "router":
            return (fs(shape[0]), None)
        if leaf == "conv":
            return (None, md(shape[1]))
        if leaf in ("w_dt", "w_B", "w_C", "w_if"):
            return (md(shape[0]), None)
        if leaf in ("A_log", "D", "dt_bias", "if_bias"):
            return (None,)
        if leaf in ("w_q", "w_k"):  # mLSTM square projections
            return (None, md(shape[1]))
        if leaf == "head_norm":
            return (None, None)
        if leaf == "W" and len(shape) == 4:   # sLSTM gates (d,4,H,dh)
            return (fs(shape[0]), None, None, md(shape[3]))
        if leaf == "R" and len(shape) == 4:   # sLSTM recurrent (4,H,dh,dh)
            return (None, None, None, md(shape[3]))
        if leaf == "bias" and len(shape) == 3:
            return (None, None, None)
        return tuple([None] * len(shape))

    spec = rule()
    assert len(spec) == len(shape), (leaf, spec, shape)
    if stacked:
        spec = (None,) + spec
    return P(*spec)


def param_shardings(cfg, params_tree, mesh, train: bool):
    def one(path, leaf):
        spec = param_pspec(cfg, path, leaf.shape, mesh, train)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, params_tree)


# --------------------------------------------------------------------------
# Cache specs (decode)
# --------------------------------------------------------------------------

def cache_pspec(cfg, path: tuple, shape: tuple, mesh, long_ctx: bool) -> P:
    names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    leaf = names[-1]
    mdl = _axis(mesh, "model")
    kva = kv_axis(cfg, mesh)
    data = _axis(mesh, "data")
    if long_ctx:
        b = None
        seq = data
    else:
        b = batch_axes(mesh, shape[1]) or None
        seq = None
    if leaf in ("k", "v"):
        # (R, B, size, n_kv, d_head)
        s = _divisible(shape[2], mesh, seq) if seq else None
        return P(None, b, s, kva, None)
    if leaf == "conv":
        return P(None, b, None, _divisible(shape[3], mesh, mdl))
    if leaf == "h" and len(shape) == 5:       # mamba (R,B,H,N,P)
        return P(None, b, _divisible(shape[2], mesh, mdl), None, None)
    if leaf == "C" and len(shape) == 5:       # mlstm (R,B,H,dk,dv)
        dk = _divisible(shape[3], mesh, seq) if long_ctx else None
        return P(None, b, None, dk, _divisible(shape[4], mesh, mdl))
    if leaf == "n" and len(shape) == 4:       # mlstm (R,B,H,dk)
        return P(None, b, None, None)
    if leaf == "m" and len(shape) == 3:
        return P(None, b, None)
    if len(shape) == 4:                       # slstm h/c/n/m (R,B,H,dh)
        return P(None, b, None, _divisible(shape[3], mesh, mdl))
    return P(*([None] * len(shape)))


def cache_shardings(cfg, cache_tree, mesh, long_ctx: bool):
    def one(path, leaf):
        return NamedSharding(mesh, cache_pspec(cfg, path, leaf.shape, mesh,
                                               long_ctx))
    return jax.tree_util.tree_map_with_path(one, cache_tree)


# --------------------------------------------------------------------------
# Input specs
# --------------------------------------------------------------------------

def input_shardings(cfg, specs: dict, mesh):
    out = {}
    for name, s in specs.items():
        b = batch_axes(mesh, s.shape[0]) or None
        rest = [None] * (len(s.shape) - 1)
        out[name] = NamedSharding(mesh, P(b, *rest))
    return out
