"""Roofline accounting: parse collective ops out of compiled HLO text.

``cost_analysis()`` does not expose collective traffic, so we scan the
optimized HLO for all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops, decode result shapes + replica groups, and apply
ring-algorithm effective-bytes factors (per participating device):

  all-reduce          2 * R * (g-1)/g          (R = result bytes)
  all-gather          R * (g-1)/g
  reduce-scatter      R * (g-1)               (input = R * g)
  all-to-all          R * (g-1)/g
  collective-permute  R

t_collective = sum(per-device effective bytes) / link_bw, which matches the
brief's ``collective_bytes / (chips * link_bw)`` with collective_bytes summed
over all chips.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip()]
        return max(1, len(ids))
    return default


def collective_stats(hlo_text: str, n_devices: int) -> dict:
    """Per-op-type counts and per-device effective bytes."""
    stats = defaultdict(lambda: {"count": 0, "raw_bytes": 0, "eff_bytes": 0.0})
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if m is None:
            continue
        shape_txt, op = m.group(1), m.group(2)
        rb = _shape_bytes(shape_txt)
        if rb == 0:
            continue
        g = _group_size(line, n_devices)
        if op == "all-reduce":
            eff = 2.0 * rb * (g - 1) / g
        elif op == "all-gather":
            eff = rb * (g - 1) / g
        elif op == "reduce-scatter":
            eff = float(rb) * (g - 1)
        elif op == "all-to-all":
            eff = rb * (g - 1) / g
        else:  # collective-permute
            eff = float(rb)
        s = stats[op]
        s["count"] += 1
        s["raw_bytes"] += rb
        s["eff_bytes"] += eff
    total = {"count": sum(s["count"] for s in stats.values()),
             "eff_bytes": sum(s["eff_bytes"] for s in stats.values())}
    return {"by_op": dict(stats), "total": total}


# TPU v5e-class constants (per the brief)
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_eff_bytes_per_dev: float) -> dict:
    t_c = flops_per_dev / PEAK_FLOPS_BF16
    t_m = bytes_per_dev / HBM_BW
    t_n = coll_eff_bytes_per_dev / ICI_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_n),
              key=lambda kv: kv[1])
    return {"t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_n,
            "dominant": dom[0],
            "roofline_s": max(t_c, t_m, t_n)}
