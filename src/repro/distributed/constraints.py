"""Activation sharding constraints.

GSPMD propagates the FSDP weight sharding (embed d-dim on 'data') into the
embedding gather's OUTPUT, which steals the 'data' axis from the batch dim
and replicates every downstream activation across data-parallel devices
(found via the HLO byte breakdown — §Perf iteration 2).  Production
frameworks pin activation shardings explicitly; this helper constrains the
leading (batch) dim to the DP axes whenever a mesh context is active and
the batch divides.
"""
from __future__ import annotations

import contextlib
import math

import jax
from jax.sharding import PartitionSpec as P

# our own mesh context: `with mesh:` (legacy resource env) does not
# populate jax.sharding.get_abstract_mesh() in this JAX version, so the
# launchers install the mesh here explicitly.
_ACTIVE_MESH = None


@contextlib.contextmanager
def activation_mesh(mesh):
    global _ACTIVE_MESH
    prev = _ACTIVE_MESH
    _ACTIVE_MESH = mesh
    try:
        yield
    finally:
        _ACTIVE_MESH = prev


def _current_mesh():
    if _ACTIVE_MESH is not None:
        return _ACTIVE_MESH
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is None or not m.axis_names:
            return None
        return m
    except Exception:
        return None


def pin(x, kinds):
    """Constrain x dim-by-dim: kinds[i] in {"batch", "model", None}.

    "batch" pins to the DP axes ('pod','data'); "model" to the TP axis;
    None replicates.  Dims that do not divide their axis fall back to
    None (with_sharding_constraint requires divisibility)."""
    mesh = _current_mesh()
    if mesh is None:
        return x
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    parts = []
    for dim, kind in zip(x.shape, kinds):
        if kind == "batch" and dp and dim % math.prod(
                mesh.shape[a] for a in dp) == 0:
            parts.append(dp if len(dp) > 1 else dp[0])
        elif kind == "model" and "model" in mesh.axis_names \
                and dim % mesh.shape["model"] == 0:
            parts.append("model")
        else:
            parts.append(None)
    if all(p is None for p in parts):
        return x
    sh = jax.sharding.NamedSharding(mesh, P(*parts))
    return jax.lax.with_sharding_constraint(x, sh)


def constrain_batch(x):
    """Pin x's leading dim to the DP axes, rest replicated."""
    return pin(x, ("batch",) + (None,) * (max(x.ndim, 1) - 1))


def dp_extent():
    """Product of the data-parallel axis sizes of the active mesh (None
    when no mesh is active) — lets mesh-agnostic model code (MoE grouping)
    match its tiling to the actual DP degree."""
    mesh = _current_mesh()
    if mesh is None:
        return None
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if not dp:
        return None
    return math.prod(mesh.shape[a] for a in dp)
