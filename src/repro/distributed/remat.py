"""Activation-checkpoint (remat) policies for the scanned train step.

The policy trades the memory roofline term (bytes re-read in backward)
against temp HBM (live activations) — §Perf discusses why full remat is
the right default at 16 seqs/device on 16 GB v5e chips.
"""
from __future__ import annotations

import jax

POLICIES = {
    # recompute everything in backward: minimal live memory
    "full": jax.checkpoint_policies.nothing_saveable,
    # keep matmul outputs (no batch dims) — classic "checkpoint dots"
    "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    # keep everything (no remat): max memory, min recompute
    "none": jax.checkpoint_policies.everything_saveable,
}


def wrap(body, policy: str = "full"):
    if policy == "none":
        return body
    return jax.checkpoint(body, policy=POLICIES[policy])
