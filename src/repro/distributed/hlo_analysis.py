"""Trip-count-aware analysis of optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any
scan-over-layers model is undercounted by the trip count (verified
empirically — see EXPERIMENTS.md §Dry-run notes).  The optimized HLO does
annotate every while with ``known_trip_count``, so this module re-derives
the roofline inputs directly from the compiled artifact:

  flops      dot ops: 2 * prod(result dims) * prod(contracting dims),
             scaled by the product of enclosing loop trip counts
  bytes      per top-level op (fusion/dot/collective/...): operands + result
             — XLA has already fused, so operand/result sizes of the
             remaining nodes model HBM traffic
  collectives ring-effective bytes per device (see collectives.py), scaled

Per-device numbers: the artifact analyzed is the SPMD-partitioned module.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "opaque": 0,
}

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_SHAPE_TOK = re.compile(r"(\w+)\[([\d,]*)\]")
_OPERANDS = re.compile(r"\(([^)]*)\)")
_TRIP = re.compile(r"known_trip_count[^0-9]*(\d+)")


def _split_shape_op(rhs: str):
    """'(s32[], f32[..]) while(%t), ...' -> (shape_txt, op, rest)."""
    rhs = rhs.strip()
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    shape = rhs[:i + 1]
                    rest = rhs[i + 1:].strip()
                    break
        else:
            return None
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        shape, rest = rhs[:sp], rhs[sp + 1:].strip()
    m = re.match(r"([\w\-]+)\(", rest)
    if not m:
        return None
    return shape, m.group(1), rest
_BODY = re.compile(r"body=%?([\w\.\-]+)")
_CALLS = re.compile(r"calls=%?([\w\.\-]+)")
_TOAPPLY = re.compile(r"to_apply=%?([\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_C = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_dims(txt: str):
    """All (dtype, dims) tokens in a (possibly tuple) shape string."""
    out = []
    for dt, dims in _SHAPE_TOK.findall(txt):
        if dt in _DTYPE_BYTES:
            d = [int(x) for x in dims.split(",")] if dims else []
            out.append((dt, d))
    return out


def _shape_bytes(txt: str) -> int:
    total = 0
    for dt, dims in _shape_dims(txt):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Instr:
    name: str
    shape_txt: str
    op: str
    rest: str


@dataclass
class Computation:
    name: str
    instrs: list = field(default_factory=list)
    shapes: dict = field(default_factory=dict)   # instr name -> shape_txt


def parse_module(hlo: str) -> dict:
    comps = {}
    cur = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if cur is None:
            m = _COMP_HDR.match(stripped)
            if m:
                cur = Computation(m.group(1))
            continue
        if stripped.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        so = _split_shape_op(rhs)
        if so is None:
            continue
        shape_txt, op, rest = so
        cur.instrs.append(Instr(name, shape_txt, op, rest))
        cur.shapes[name] = shape_txt
    return comps


def _operand_names(rest: str):
    m = _OPERANDS.search(rest[rest.index("("):] if "(" in rest else rest)
    if not m:
        return []
    names = []
    for tok in m.group(1).split(","):
        tok = tok.strip()
        # operands may be "%name" or "f32[..] %name" or bare names
        mm = re.search(r"%?([\w\.\-]+)\s*$", tok)
        if mm:
            names.append(mm.group(1))
    return names


def _dot_flops(comp: Computation, ins: Instr) -> float:
    res = _shape_dims(ins.shape_txt)
    if not res:
        return 0.0
    n_out = 1
    for d in res[0][1]:
        n_out *= d
    ops = _operand_names(ins.rest)
    lhs_shape = comp.shapes.get(ops[0] if ops else "", "")
    lhs_dims_list = _shape_dims(lhs_shape)
    lhs_dims = lhs_dims_list[0][1] if lhs_dims_list else []
    mc = _LHS_C.search(ins.rest)
    k = 1
    if mc and lhs_dims:
        for ix in (int(x) for x in mc.group(1).split(",") if x.strip()):
            if ix < len(lhs_dims):
                k *= lhs_dims[ix]
    return 2.0 * n_out * k


def _group_size(rest: str, default: int) -> int:
    m = _GROUPS_IOTA.search(rest)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST.search(rest)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip()]
        return max(1, len(ids))
    return default


def _coll_eff_bytes(op: str, rest: str, shape_txt: str, n_dev: int) -> float:
    rb = _shape_bytes(shape_txt)
    # async -start ops repeat shape of operands in result tuple; use half
    g = _group_size(rest, n_dev)
    if g <= 1:
        return 0.0
    if op.startswith("all-reduce"):
        return 2.0 * rb * (g - 1) / g
    if op.startswith("all-gather"):
        return rb * (g - 1) / g
    if op.startswith("reduce-scatter"):
        return float(rb) * (g - 1)
    if op.startswith("all-to-all"):
        return rb * (g - 1) / g
    return float(rb)  # collective-permute


class Analyzer:
    def __init__(self, hlo: str, n_devices: int):
        self.comps = parse_module(hlo)
        self.n_dev = n_devices
        self._memo = {}

    def total(self, name: str) -> dict:
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        z = {"flops": 0.0, "bytes": 0.0, "coll_eff_bytes": 0.0,
             "coll_by_op": defaultdict(float), "coll_count": 0.0,
             "bytes_by_op": defaultdict(float), "top": []}
        if comp is None:
            self._memo[name] = z
            return z
        self._memo[name] = z  # break cycles
        for ins in comp.instrs:
            op = ins.op
            base = op.replace("-start", "")
            if base in COLLECTIVES:
                eff = _coll_eff_bytes(base, ins.rest, ins.shape_txt,
                                      self.n_dev)
                z["coll_eff_bytes"] += eff
                z["coll_by_op"][base] += eff
                z["coll_count"] += 1
                z["bytes"] += _shape_bytes(ins.shape_txt)
                z["bytes_by_op"][base] += _shape_bytes(ins.shape_txt)
            elif op == "dot":
                z["flops"] += _dot_flops(comp, ins)
                b = self._io_bytes(comp, ins)
                z["bytes"] += b
                z["bytes_by_op"]["dot"] += b
            elif op == "fusion" or op == "custom-call":
                # bytes: fusion I/O only (internals live in registers/VMEM —
                # recursing would double-count); flops/collectives: recurse
                b = self._io_bytes(comp, ins)
                z["bytes"] += b
                z["bytes_by_op"]["fusion"] += b
                if b > 1e6:
                    z["top"].append((b, f"fusion {ins.name} "
                                     f"{ins.shape_txt[:60]}"))
                m = _CALLS.search(ins.rest) or _TOAPPLY.search(ins.rest)
                if m:
                    self._add(z, self.total(m.group(1)), 1.0,
                              include_bytes=False)
            elif op == "while":
                m = _BODY.search(ins.rest)
                t = _TRIP.search(ins.rest)
                trips = float(t.group(1)) if t else 1.0
                if m:
                    self._add(z, self.total(m.group(1)), trips)
            elif op == "conditional":
                m = _BRANCHES.search(ins.rest)
                if m:
                    subs = [self.total(s.strip().lstrip("%"))
                            for s in m.group(1).split(",")]
                    if subs:
                        best = max(subs, key=lambda s: s["flops"] + s["bytes"])
                        self._add(z, best, 1.0)
            elif op == "call":
                m = _TOAPPLY.search(ins.rest)
                if m:
                    self._add(z, self.total(m.group(1)), 1.0)
            elif op == "dynamic-update-slice":
                # in-place: traffic ~ 2x the updated region, not the buffer
                ops_ = _operand_names(ins.rest)
                upd = _shape_bytes(comp.shapes.get(ops_[1], "")) if \
                    len(ops_) > 1 else 0
                z["bytes"] += 2.0 * upd
                z["bytes_by_op"]["dus"] += 2.0 * upd
            elif op in ("dynamic-slice", "slice", "transpose", "copy",
                        "broadcast", "iota", "reshape", "bitcast"):
                b = 2.0 * _shape_bytes(ins.shape_txt)
                z["bytes"] += b
                z["bytes_by_op"][op] += b
            elif op in ("convolution", "scatter", "gather", "sort", "reduce",
                        "reduce-window", "select-and-scatter",
                        "concatenate", "pad",
                        "add", "multiply", "subtract", "divide", "exponential",
                        "tanh", "compare", "select", "convert",
                        "reverse", "map", "rng", "rng-bit-generator"):
                b = self._io_bytes(comp, ins)
                z["bytes"] += b
                z["bytes_by_op"][op] += b
        z["coll_by_op"] = dict(z["coll_by_op"])
        return z

    def _io_bytes(self, comp: Computation, ins: Instr) -> float:
        b = float(_shape_bytes(ins.shape_txt))
        for o in _operand_names(ins.rest):
            b += _shape_bytes(comp.shapes.get(o, ""))
        return b

    @staticmethod
    def _add(z, sub, mult, include_bytes=True):
        z["flops"] += sub["flops"] * mult
        if include_bytes:
            z["bytes"] += sub["bytes"] * mult
            for k, v in sub["bytes_by_op"].items():
                z["bytes_by_op"][k] = z["bytes_by_op"].get(k, 0.0) + v * mult
            z["top"] = sorted(
                z["top"] + [(b * mult, f"{d} x{mult:g}")
                            for b, d in sub.get("top", [])],
                key=lambda t: -t[0])[:12]
        z["coll_eff_bytes"] += sub["coll_eff_bytes"] * mult
        z["coll_count"] += sub["coll_count"] * mult
        for k, v in sub["coll_by_op"].items():
            z["coll_by_op"][k] = z["coll_by_op"].get(k, 0.0) + v * mult

    def entry(self) -> dict:
        # entry computation = the one not referenced by others; use the
        # longest named 'main' if present
        for name in self.comps:
            if name.startswith("main"):
                return self.total(name)
        # fallback: largest
        best, bz = None, -1
        for name in self.comps:
            t = self.total(name)
            if t["flops"] + t["bytes"] > bz:
                best, bz = t, t["flops"] + t["bytes"]
        return best or {}


def analyze(hlo: str, n_devices: int) -> dict:
    a = Analyzer(hlo, n_devices)
    out = dict(a.entry())
    out["coll_by_op"] = dict(out.get("coll_by_op", {}))
    out["bytes_by_op"] = dict(out.get("bytes_by_op", {}))
    return out
