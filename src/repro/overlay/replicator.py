"""Pull-based cross-node KV page replication (the overlay half of page
migration; the arena half is serving/engine.export_pages/import_pages).

When ``decide()`` finds the deepest prefix holder vetoed by memory or
load pressure it routes the request to a peer that CAN host it, with a
fetch hint naming the holder and hit depth.  This module is that peer's
state machine: it sends one ``kv_fetch`` (digest chain + depth) per
distinct prefix, reassembles the holder's chunked ``kv_pages`` stream,
imports the pages, and only then serves the request — which now admits
with a local prefix hit and zero prefill dispatches for the replicated
blocks.  Requests for a prefix whose fetch is already in flight piggyback
on it instead of fetching again.

Replication is an optimization, never a correctness dependency: a
refusal (holder evicted the entry, or is under its own export-pressure
gate), an ``OutOfPages`` on import, or a timeout all fall back to plain
prefill of the same request.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field

from repro.overlay.user_node import _decode
from repro.serving.prefix_cache import _chain_hashes


@dataclass
class _Fetch:
    chains: list               # leading digests, chains[i] keys blocks 0..i
    depth: int                 # blocks requested
    holder: object
    waiters: list = field(default_factory=list)   # payloads served on finish
    chunks: dict = field(default_factory=dict)    # seq -> bytes
    total: int = -1
    done: bool = False


class Replicator:
    def __init__(self, node, timeout_s: float = 30.0):
        self.node = node
        self.timeout_s = timeout_s
        self._fid = itertools.count(1)
        self._fetches: dict = {}       # fetch_id -> _Fetch
        self._by_key: dict = {}        # chain digest -> in-flight fetch_id

    # ------------------------------------------------------------------
    def request(self, net, payload: dict, holder, depth: int) -> bool:
        """Pull ``depth`` blocks of the request's prefix from ``holder``
        before serving.  Returns True when this state machine took the
        request (it WILL be served on completion or fallback); False when
        there is nothing to fetch — caller serves immediately."""
        node = self.node
        eng = node.real_engine
        if eng is None or not getattr(eng, "paged", False):
            return False
        toks = [int(t) for t in payload["prompt"]]
        depth = min(int(depth), len(toks) // eng.block)
        if depth < 1 or holder == node.node_id:
            return False
        prefix = toks[:depth * eng.block]
        matched, _ = eng.prefix_cache.peek(prefix)
        if matched >= depth * eng.block:
            return False               # an earlier fetch already landed it
        chains = _chain_hashes(prefix, eng.block)[:depth]
        # dedupe across DEPTHS too: every depth of an in-flight fetch is
        # keyed, so a deeper or shallower hint for the same prefix
        # piggybacks (deepest shared digest wins) instead of re-shipping
        # the pages the first fetch already has on the wire
        for c in reversed(chains):
            fid = self._by_key.get(c)
            if fid is not None and fid in self._fetches:
                self._fetches[fid].waiters.append(payload)
                node.metrics["kv_fetch_piggybacks"] += 1
                self._park(1)
                return True
        fid = next(self._fid)
        self._fetches[fid] = _Fetch(chains, depth, holder, [payload])
        for c in chains:
            self._by_key[c] = fid
        node.metrics["kv_fetches"] += 1
        self._park(1)
        net.send(node.node_id, holder,
                 {"type": "kv_fetch", "from": node.node_id,
                  "fetch_id": fid, "chains": chains, "depth": depth},
                 size_bytes=64 + 16 * len(chains))
        net.call_after(self.timeout_s, self._timeout, net, fid)
        return True

    def _park(self, n: int):
        """Count parked requests as active load: a fetch window can span
        seconds, and an hr_sync broadcasting active=0 meanwhile would
        keep attracting siblings onto the very node that is still
        waiting for the pages (the burst the load veto exists to stop).
        ``_serve`` re-increments when the waiter actually admits."""
        node = self.node
        node.active_requests = max(0, node.active_requests + n)
        me = node.peers.get(node.node_id)
        if me is not None:
            me.active_requests = node.active_requests

    # ------------------------------------------------------------------
    def on_pages(self, net, msg: dict):
        """One ``kv_pages`` chunk (or refusal) from the holder."""
        f = self._fetches.get(msg["fetch_id"])
        if f is None or f.done:
            return                     # late chunk after timeout/refusal
        node = self.node
        if not msg.get("ok"):
            node.metrics["kv_refusals"] += 1
            self._finish(net, msg["fetch_id"], imported=False)
            return
        f.chunks[int(msg["seq"])] = bytes(msg["data"])
        f.total = int(msg["total"])
        node.metrics["kv_wire_bytes"] += len(msg["data"])
        if len(f.chunks) < f.total:
            return
        # any failure from here on — OutOfPages, a truncated/garbled blob
        # from a byzantine or version-skewed holder, a shape mismatch —
        # must degrade to plain prefill, never escape into the node's
        # message loop (import_pages releases its pages on the way out)
        try:
            buf = _decode(b"".join(f.chunks[i] for i in range(f.total)))
            # the holder may cover fewer blocks than requested (partial
            # eviction since the sketch broadcast): import what arrived
            depth = min(int(msg.get("depth", f.depth)), f.depth)
            n_pages = int(buf["n_pages"])
            self.node.real_engine.import_pages(buf, f.chains[:depth])
        except Exception:            # OutOfPages included
            node.metrics["kv_import_failures"] += 1
            self._finish(net, msg["fetch_id"], imported=False)
            return
        node.metrics["kv_imported_pages"] += n_pages
        self._finish(net, msg["fetch_id"], imported=True)

    # ------------------------------------------------------------------
    def _timeout(self, net, fid: int):
        f = self._fetches.get(fid)
        if f is not None and not f.done:
            self.node.metrics["kv_timeouts"] += 1
            self._finish(net, fid, imported=False)

    def _finish(self, net, fid: int, imported: bool):
        f = self._fetches.pop(fid)
        f.done = True
        for c in f.chains:
            if self._by_key.get(c) == fid:
                self._by_key.pop(c)
        if not imported:
            self.node.metrics["kv_fallbacks"] += len(f.waiters)
        self._park(-len(f.waiters))    # _serve re-counts each admission
        for payload in f.waiters:      # admission now aliases the
            self.node._serve(net, payload)   # imported pages (or prefills)
