"""Committee-maintained node registry (§3.1).

Verification nodes' IPs/pubkeys are public.  Users/model nodes register
(id, pubkey, region, hw_score); the committee signs the resulting lists —
a list is valid iff > 2/3 of the committee signed it.  Regions partition
large deployments (>=1000 users per region for anonymity; model groups
split at 50, §3.3).
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field
from typing import Optional

from repro.core import ed25519

MODEL_GROUP_MAX = 50
REGION_MIN_USERS = 1000


@dataclass
class NodeRecord:
    node_id: object
    pubkey: bytes = b""
    dh_pub: bytes = b""
    region: str = "r0"
    hw_score: float = 5.0
    llm: str = ""


def _digest(records: list) -> bytes:
    payload = json.dumps(
        [[str(r.node_id), r.pubkey.hex(), r.dh_pub.hex(), r.region,
          r.hw_score, r.llm] for r in sorted(records,
                                             key=lambda r: str(r.node_id))]
    ).encode()
    return hashlib.sha256(payload).digest()


@dataclass
class SignedList:
    records: list
    signatures: dict = field(default_factory=dict)  # vn_id -> sig

    def digest(self) -> bytes:
        return _digest(self.records)

    def verify(self, committee_pubs: dict) -> bool:
        d = self.digest()
        ok = sum(1 for vn, sig in self.signatures.items()
                 if vn in committee_pubs
                 and ed25519.verify(committee_pubs[vn], d, sig))
        return 3 * ok > 2 * len(committee_pubs)


class Registry:
    """In-committee registry state (replicated via the BFT layer)."""

    def __init__(self, committee_keys: dict, use_crypto: bool = True):
        self.committee_keys = committee_keys      # vn_id -> SigningKey
        self.committee_pubs = {k: v.public for k, v in committee_keys.items()}
        self.users: dict = {}
        self.models: dict = {}
        self.use_crypto = use_crypto

    def register_user(self, rec: NodeRecord):
        self.users[rec.node_id] = rec

    def register_model(self, rec: NodeRecord):
        self.models[rec.node_id] = rec

    def deregister(self, node_id):
        self.users.pop(node_id, None)
        self.models.pop(node_id, None)

    def _sign(self, records: list) -> SignedList:
        sl = SignedList(records)
        if self.use_crypto:
            d = sl.digest()
            for vn, key in self.committee_keys.items():
                sl.signatures[vn] = key.sign(d)
        return sl

    def user_list(self, region: Optional[str] = None) -> SignedList:
        recs = [r for r in self.users.values()
                if region is None or r.region == region]
        return self._sign(recs)

    def model_list(self, llm: Optional[str] = None,
                   region: Optional[str] = None) -> SignedList:
        recs = [r for r in self.models.values()
                if (llm is None or r.llm == llm)
                and (region is None or r.region == region)]
        return self._sign(recs)

    def model_groups(self, llm: str) -> list[list]:
        """Split a logical group above MODEL_GROUP_MAX (by region first)."""
        recs = [r for r in self.models.values() if r.llm == llm]
        by_region: dict = {}
        for r in recs:
            by_region.setdefault(r.region, []).append(r)
        groups = []
        for region, rs in sorted(by_region.items()):
            for i in range(0, len(rs), MODEL_GROUP_MAX):
                groups.append(rs[i:i + MODEL_GROUP_MAX])
        return groups
