"""Model node: serving engine + HR-tree state sync + overlay forwarding
(§3.3, Fig 5) + signed responses (§3.4).

On receiving >= k prompt cloves it recovers the request, runs Algorithm 2
(HR-tree match -> cache-affinity pick, else least-relative-load), serves or
forwards, and returns the response as S-IDA cloves through the user's
proxies.  Every ``sync_every`` sim-seconds it broadcasts its cached-prefix
hash paths + load to the group.
"""
from __future__ import annotations

import dataclasses
import itertools
import random
from dataclasses import dataclass, field
from typing import Optional

from repro.core import ed25519, hrtree, sentry, sida
from repro.core.forwarding import ForwardingConfig, PeerInfo, decide
from repro.overlay.replicator import Replicator
from repro.overlay.user_node import _decode, _encode
from repro.serving.engine import LatencyEngine, LatencyEngineConfig
from repro.serving.page_pool import PagedHandle


@dataclass
class PendingRequest:
    cloves: dict = field(default_factory=dict)
    done: bool = False


class ModelNode:
    def __init__(self, node_id, llm: str = "llm", hw_score: float = 5.0,
                 engine: Optional[LatencyEngine] = None,
                 fwd_cfg: ForwardingConfig = ForwardingConfig(),
                 chunk_lengths=(64,), sync_every: float = 5.0,
                 real_engine=None, use_crypto: bool = True,
                 behaviour: str = "honest", kv_chunk_bytes: int = 1 << 16,
                 kv_fetch_timeout: float = 30.0):
        self.node_id = node_id
        self.llm = llm
        self.hw_score = hw_score
        self.engine = engine or LatencyEngine(
            LatencyEngineConfig(hw_score=hw_score))
        self.real_engine = real_engine      # optional RealEngine (tiny cfg)
        # real-engine requests go through the slot-pool batched scheduler:
        # submitted at admission (_serve), drained at completion (_finish),
        # so requests overlapping on the sim clock share decode dispatches
        self._real_sched = None
        self._real_rid = itertools.count(1)
        self._rid_by_msg: dict = {}
        self._real_results: dict = {}
        self.fwd_cfg = fwd_cfg
        self.sync_every = sync_every
        self.use_crypto = use_crypto
        self.behaviour = behaviour          # honest | swap_model | drop
        # ablations (Fig 16): full = HR-tree + load balance, lb_only = load
        # balance without the HR-tree, none = always serve locally
        self.fwd_mode = "full"
        if use_crypto:
            self.sign_key = ed25519.SigningKey()
            self.public = self.sign_key.public
        else:
            self.sign_key, self.public = None, bytes(32)
        self.sentry = sentry.Sentry()
        self.lengths = list(chunk_lengths)
        self.hrtree = hrtree.HRTree(self.lengths)
        self.peers: dict = {}               # node_id -> PeerInfo
        self.group: list = []               # group member ids
        self._pending: dict = {}
        self._recent_prompts: list = []     # token streams for sync
        self.active_requests = 0
        self.metrics = {"served": 0, "forwarded_in": 0, "forwarded_out": 0,
                        "cache_hits": 0, "affinity_hits": 0,
                        "ttft": [], "total": [],
                        "cached_tokens": 0, "prompt_tokens": 0,
                        # cross-node KV page migration
                        "replicate_routes": 0,     # decide() chose replicate
                        "kv_fetches": 0,           # kv_fetch messages sent
                        "kv_fetch_piggybacks": 0,  # requests joining a fetch
                        "kv_imported_pages": 0,
                        "kv_refusals": 0,          # holder said no
                        "kv_import_failures": 0,   # local OutOfPages
                        "kv_timeouts": 0,
                        "kv_fallbacks": 0,         # requests that prefilled
                        "kv_wire_bytes": 0,        # payload bytes received
                        "kv_exports": 0,           # fetches served as holder
                        "kv_export_refused": 0}
        self.kv_chunk_bytes = kv_chunk_bytes
        self.replicator = Replicator(self, timeout_s=kv_fetch_timeout)
        self.respond_fn = None              # (tokens)->(out_tokens) override

    # ------------------------------------------------------------------
    def join_group(self, members: list):
        self.group = [m for m in members]
        for m in self.group:
            if m != self.node_id:
                self.peers.setdefault(m, PeerInfo(m))
        self.peers[self.node_id] = PeerInfo(self.node_id, self.hw_score)

    def start(self, net):
        net.call_after(self.sync_every * (0.5 + random.random() * 0.5),
                       self._sync_tick, net)

    # ------------------------------------------------------------------
    # state synchronization (§3.3)
    # ------------------------------------------------------------------
    def _sync_tick(self, net):
        self.broadcast_state(net)
        net.call_after(self.sync_every, self._sync_tick, net)

    def broadcast_state(self, net):
        paths = []
        for toks in self._recent_prompts[-64:]:
            h = hrtree.preprocess(toks, self.lengths)
            if h:
                paths.append(h)
        sketch = self._prefix_sketch()
        msg = {"type": "hr_sync", "from": self.node_id,
               "paths": paths,
               "active": self.active_requests,
               "hw": self.hw_score,
               "kv_usage": self.engine.prefix_cache.used_bytes
               if self.engine else 0,
               # paged real engine: free-page pressure (fraction of the KV
               # arena in use) — a truer admission signal than slot count,
               # since memory, not rows, is what blocks admission
               "kv_pressure": self._kv_pressure(),
               # speculative-decode accept rate: how many draft tokens per
               # verify dispatch this node's engine commits — reported so
               # routing can become accept-rate-aware (ROADMAP)
               "spec_accept_rate": self._spec_accept_rate(),
               # block-digest bloom over the serving cache: peers route
               # sibling requests to the deepest sketch hit (prefix
               # affinity) instead of re-prefilling on a load-picked node
               "sketch": sketch}
        size = 32 + sum(len(p) for p in paths) + len(sketch)
        for m in self.group:
            if m != self.node_id:
                net.send(self.node_id, m, msg, size_bytes=size)
        # local view of self
        self.hrtree.merge_paths(paths, self.node_id)
        me = self.peers[self.node_id]
        me.active_requests = self.active_requests
        me.hw_score = self.hw_score
        me.kv_pressure = self._kv_pressure()
        me.spec_accept_rate = self._spec_accept_rate()
        me.prefix_sketch = sketch

    def _prefix_sketch(self) -> bytes:
        """Serialized PrefixSketch over the serving prefix cache.  A real
        engine's cache is the physical truth (its pages are what admission
        aliases); the latency model's cache mirrors served prompts."""
        pc = (self.real_engine.prefix_cache if self.real_engine is not None
              else self.engine.prefix_cache if self.engine else None)
        return pc.sketch_bytes() if pc is not None else b""

    def _kv_pressure(self) -> float:
        """Fraction of the paged KV arena in use (0 when no paged real
        engine is attached — the latency model has no physical pool)."""
        eng = self.real_engine
        if eng is None or not getattr(eng, "paged", False):
            return 0.0
        alloc = eng.allocator
        return alloc.used_count / max(1, alloc.num_pages - 1)

    def _spec_accept_rate(self) -> float:
        """Speculative-draft accept fraction of the attached real engine
        (0 when there is none, or it has not drafted yet)."""
        eng = self.real_engine
        if eng is None:
            return 0.0
        return getattr(eng, "spec_accept_rate", 0.0)

    def _handle_sync(self, net, msg):
        nid = msg["from"]
        p = self.peers.setdefault(nid, PeerInfo(nid))
        p.active_requests = msg["active"]
        p.hw_score = msg["hw"]
        p.kv_usage = msg.get("kv_usage", 0)
        p.kv_pressure = msg.get("kv_pressure", 0.0)
        p.spec_accept_rate = msg.get("spec_accept_rate", 0.0)
        p.prefix_sketch = msg.get("sketch") or None
        self.hrtree.merge_paths(msg["paths"], nid)

    # ------------------------------------------------------------------
    # cross-node KV page migration: holder side
    # ------------------------------------------------------------------
    def _handle_kv_fetch(self, net, msg):
        """A peer asks for the prefix pages behind a digest chain.

        Serve the deepest covered prefix as a chunked ``kv_pages`` stream
        (export is read-only: refcounts and LRU order are untouched, so
        shipping never blocks local serving).  Refuse when the entry was
        evicted since the sketch broadcast that attracted the fetch, or
        when this node's own arena pressure says the entry is about to go
        — the fetcher then falls back to plain prefill."""
        src, fid = msg["from"], msg["fetch_id"]
        eng = self.real_engine
        chains = [bytes(c) for c in msg["chains"]]
        depth = min(int(msg["depth"]), len(chains))
        entry, d_cov = None, 0
        if (eng is not None and getattr(eng, "paged", False)
                and self._kv_pressure() <= self.fwd_cfg.export_pressure_max):
            for d in range(depth, 0, -1):
                e = eng.prefix_cache.entry_by_chain(chains[d - 1])
                if (e is not None and isinstance(e.handle, PagedHandle)
                        and e.length >= d * eng.block
                        and len(e.handle.pages) >= d):
                    entry, d_cov = e, d
                    break
        if entry is None:
            self.metrics["kv_export_refused"] += 1
            net.send(self.node_id, src,
                     {"type": "kv_pages", "from": self.node_id,
                      "fetch_id": fid, "ok": False}, size_bytes=64)
            return
        blob = _encode(eng.export_pages(entry.handle, depth=d_cov))
        step = max(1, int(self.kv_chunk_bytes))
        chunks = [blob[i:i + step] for i in range(0, len(blob), step)]
        for seq, data in enumerate(chunks):
            net.send(self.node_id, src,
                     {"type": "kv_pages", "from": self.node_id,
                      "fetch_id": fid, "ok": True, "seq": seq,
                      "total": len(chunks), "depth": d_cov, "data": data},
                     size_bytes=len(data) + 96)
        self.metrics["kv_exports"] += 1

    # ------------------------------------------------------------------
    # request path
    # ------------------------------------------------------------------
    def on_message(self, net, src, msg):
        mt = msg["type"]
        if mt == "prompt_clove":
            self._handle_clove(net, msg)
        elif mt == "hr_sync":
            self._handle_sync(net, msg)
        elif mt == "fwd_request":
            self.metrics["forwarded_in"] += 1
            hint = None
            if msg.get("kv_holder") is not None and msg.get("kv_depth"):
                hint = (msg["kv_holder"], int(msg["kv_depth"]))
            self._process(net, _decode(msg["payload"]), forwarded=True,
                          fetch_hint=hint)
        elif mt == "kv_fetch":
            self._handle_kv_fetch(net, msg)
        elif mt == "kv_pages":
            self.replicator.on_pages(net, msg)

    def _handle_clove(self, net, msg):
        clove = sida.Clove.decode(msg["clove"])
        # group by (k, n, frag len) is ambiguous — recover via msg buckets:
        # cloves of one message share identical metadata once decoded, so we
        # key the pending buckets by the proxy-announced message digest when
        # present; fall back to (n, k, len).
        key = msg.get("msg_key") or (clove.n, clove.k, len(clove.frag))
        pend = self._pending.setdefault(key, PendingRequest())
        if pend.done:
            return
        pend.cloves[clove.index] = clove
        if len(pend.cloves) >= clove.k:
            try:
                blob = sida.recover(list(pend.cloves.values()))
            except Exception:
                return
            pend.done = True
            self._process(net, _decode(blob))

    def _process(self, net, payload: dict, forwarded: bool = False,
                 fetch_hint=None):
        """``fetch_hint`` = (holder_id, depth): pull that many blocks of
        prefix pages from the holder before serving (set by a replicate-
        routed fwd_request, or locally when decide() picks self as the
        replication target)."""
        tokens = payload["prompt"]
        self.sentry.observe(tokens)
        if self.behaviour == "drop":
            return
        if not forwarded and self.fwd_mode != "none":
            if self.fwd_mode == "full":
                tree, cfg = self.hrtree, self.fwd_cfg
            else:   # lb_only ablation: no HR-tree AND no sketch affinity
                tree = type(self.hrtree)(self.lengths)
                cfg = dataclasses.replace(self.fwd_cfg, affinity=False)
            d = decide(cfg, tree, self.peers, tokens,
                       self_id=self.node_id,
                       n_out=int(payload.get("max_new", 64)))
            if d.reason in ("cache_hit", "affinity"):
                self.metrics["cache_hits"] += 1
            if d.reason == "affinity":
                self.metrics["affinity_hits"] += 1
            if d.reason == "replicate":
                self.metrics["replicate_routes"] += 1
                fetch_hint = (d.fetch_from, d.depth)
            if d.target is not None and d.target != self.node_id:
                self.metrics["forwarded_out"] += 1
                # optimistic load echo: count the in-flight forward against
                # the target's stale sync view so back-to-back arrivals
                # between sync ticks don't all herd onto the same peer
                # (the next hr_sync overwrites this with ground truth)
                if d.target in self.peers:
                    self.peers[d.target].active_requests += 1
                msg = {"type": "fwd_request", "payload": _encode(payload)}
                if d.reason == "replicate":
                    msg["kv_holder"] = d.fetch_from
                    msg["kv_depth"] = int(d.depth)
                net.send(self.node_id, d.target, msg,
                         size_bytes=len(tokens) * 2 + 128)
                return
        if fetch_hint is not None and self.replicator.request(
                net, payload, fetch_hint[0], fetch_hint[1]):
            return      # served once the pages land (or the fetch fails)
        self._serve(net, payload)

    def _serve(self, net, payload: dict):
        tokens = payload["prompt"]
        max_new = int(payload.get("max_new", 64))
        now = net.t
        self.active_requests += 1
        self.peers[self.node_id].active_requests = self.active_requests
        self.metrics["served"] += 1
        matched, _ = self.engine.prefix_cache.match(tokens)
        ttft, total = self.engine.service_times(
            len(tokens), matched, max_new, now)
        self.metrics["ttft"].append(ttft)
        self.metrics["total"].append(total)
        self.metrics["cached_tokens"] += matched
        self.metrics["prompt_tokens"] += len(tokens)
        self.engine.prefix_cache.insert(tokens, handle=None,
                                        nbytes=len(tokens) * 1024)
        self._recent_prompts.append(list(tokens))
        if len(self._recent_prompts) > 512:
            self._recent_prompts = self._recent_prompts[-256:]
        if self.real_engine is not None and self.respond_fn is None:
            self._submit_real(payload, max_new)
        net.call_after(total, self._finish, net, payload, max_new)

    # ---- real-engine path: slot-pool continuous batching ----
    def _submit_real(self, payload: dict, n_out: int):
        from repro.serving.engine import Request
        from repro.serving.scheduler import Scheduler
        if self._real_sched is None:
            self._real_sched = Scheduler(self.real_engine, max_active=4)
        rid = next(self._real_rid)
        self._rid_by_msg[payload["msg_id"]] = rid
        self._real_sched.submit(
            Request(rid, payload["prompt"], max_new=min(n_out, 16)))

    def _drain_real(self, rid: int) -> list:
        sched = self._real_sched
        while rid not in self._real_results and (sched.queue or sched.active):
            sched.step()
            for r in sched.done:
                self._real_results[r.req_id] = r.output
            sched.done.clear()
        return self._real_results.pop(rid, [])

    def _finish(self, net, payload: dict, n_out: int):
        self.active_requests = max(0, self.active_requests - 1)
        self.peers[self.node_id].active_requests = self.active_requests
        rid = self._rid_by_msg.pop(payload["msg_id"], None)
        if self.respond_fn is not None:
            if rid is not None:    # respond_fn set mid-flight: retire the
                self._drain_real(rid)   # already-submitted request so it
            out = list(self.respond_fn(payload["prompt"]))  # can't linger
        elif self.real_engine is not None:
            if rid is None:      # respond_fn was unset mid-flight; late entry
                self._submit_real(payload, n_out)
                rid = self._rid_by_msg.pop(payload["msg_id"])
            out = self._drain_real(rid)
        else:
            out = [int(x) % 1000 for x in range(n_out)]
        resp = {"msg_id": payload["msg_id"],
                "session": payload.get("session"),
                "server": self.node_id,
                "output": out,
                "prompt": payload["prompt"]}  # echoed (anti-counterfeit §4.4)
        blob = _encode(resp)
        if self.use_crypto and self.sign_key is not None:
            resp_sig = self.sign_key.sign(blob)
        else:
            resp_sig = b""
        reply = payload.get("reply", [])
        n = max(len(reply), 1)
        k = max(1, min(len(reply), n - 1)) if n > 1 else 1
        cloves = sida.make_cloves(blob, n, k) if reply else []
        for (proxy_id, pid_hex), c in zip(reply, cloves):
            net.send(self.node_id, proxy_id,
                     {"type": "response_clove", "path_id": pid_hex,
                      "clove": c.encode(), "msg_id": payload["msg_id"],
                      "sig": resp_sig.hex()},
                     size_bytes=len(c.frag) + 96)
