"""Assemble a whole GenTorrent overlay on a simulated network.

build_overlay() wires: a verification committee (registry + consensus), a
population of user nodes (each also a relay), and a group of model nodes
with engines — then establishes proxies and starts state-sync timers.
This is the entry point used by examples/ and benchmarks/.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.core import ed25519
from repro.core.consensus import VerificationCommittee
from repro.core.forwarding import ForwardingConfig
from repro.core.reputation import ReputationConfig
from repro.net.simnet import SimNet
from repro.overlay.model_node import ModelNode
from repro.overlay.registry import NodeRecord, Registry
from repro.overlay.user_node import UserNode
from repro.overlay.verification_node import VerificationNode
from repro.serving.engine import LatencyEngine, LatencyEngineConfig


@dataclass
class OverlayConfig:
    n_users: int = 40
    n_models: int = 4
    n_verifiers: int = 4
    n_proxies: int = 4
    sida_n: int = 4
    sida_k: int = 3
    latency_s: float = 0.1           # paper: 100 ms per packet
    chunk_lengths: tuple = (64,)
    sync_every: float = 5.0
    use_crypto: bool = False         # pure-python crypto is O(ms)/op;
                                     # enable for the security tests
    cache_bytes: int = 1 << 28       # per-node KV cache budget: the
                                     # HR-tree's aggregate-capacity win
                                     # appears when the working set
                                     # exceeds one node's budget
    fwd_cfg: ForwardingConfig = field(default_factory=ForwardingConfig)
    rep_cfg: ReputationConfig = field(default_factory=ReputationConfig)
    engine_cfg: Callable = LatencyEngineConfig
    hw_scores: Optional[list] = None
    seed: int = 0


@dataclass
class Overlay:
    net: SimNet
    users: list
    models: list
    verifiers: list
    registry: Registry
    committee: Optional[VerificationCommittee]
    cfg: OverlayConfig

    def user(self, i) -> UserNode:
        return self.users[i]

    def warmup(self, t: float = 5.0):
        self.net.run_until(self.net.t + t)


def build_overlay(cfg: OverlayConfig, score_fns: Optional[list] = None,
                  model_behaviours: Optional[dict] = None) -> Overlay:
    rng = random.Random(cfg.seed)
    net = SimNet(default_latency=cfg.latency_s, seed=cfg.seed)

    # --- committee / registry ---
    vn_keys = {f"vn{i}": ed25519.SigningKey(bytes([7 + i]) * 32)
               for i in range(cfg.n_verifiers)}
    registry = Registry(vn_keys, use_crypto=cfg.use_crypto)

    # --- users (each also a relay) ---
    users = []
    for i in range(cfg.n_users):
        u = UserNode(f"u{i}", rng=random.Random(rng.random()),
                     n_proxies=cfg.n_proxies, sida_n=cfg.sida_n,
                     sida_k=cfg.sida_k, use_crypto=cfg.use_crypto)
        users.append(u)
        net.add_node(u.node_id, u)
        registry.register_user(NodeRecord(u.node_id, dh_pub=u.dh_pub))

    # --- model nodes ---
    models = []
    for i in range(cfg.n_models):
        hw = (cfg.hw_scores[i] if cfg.hw_scores else 5.0)
        beh = (model_behaviours or {}).get(f"m{i}", "honest")
        m = ModelNode(f"m{i}", llm="llm", hw_score=hw,
                      engine=LatencyEngine(cfg.engine_cfg(hw_score=hw),
                                           cache_bytes=cfg.cache_bytes),
                      fwd_cfg=cfg.fwd_cfg,
                      chunk_lengths=cfg.chunk_lengths,
                      sync_every=cfg.sync_every,
                      use_crypto=cfg.use_crypto, behaviour=beh)
        models.append(m)
        net.add_node(m.node_id, m)
        registry.register_model(NodeRecord(m.node_id, hw_score=hw,
                                           llm="llm"))
    member_ids = [m.node_id for m in models]
    for m in models:
        m.join_group(member_ids)
        m.start(net)

    # --- verification nodes ---
    verifiers = []
    committee = None
    if score_fns is not None:
        assert len(score_fns) == cfg.n_verifiers
        for i in range(cfg.n_verifiers):
            v = VerificationNode(f"vn{i}", score_fns[i],
                                 rng=random.Random(1000 + i),
                                 use_crypto=cfg.use_crypto)
            verifiers.append(v)
            net.add_node(v.client.node_id, v)
        committee = VerificationCommittee(cfg.n_verifiers, score_fns,
                                          rep_cfg=cfg.rep_cfg)

    # --- bootstrap: lists + proxies ---
    ul = registry.user_list()
    ml = registry.model_list()
    pubs = registry.committee_pubs if cfg.use_crypto else None
    for u in users:
        u.load_lists(ul, ml, pubs)
        u.establish_proxies(net)
    for v in verifiers:
        v.client.load_lists(ul, ml, pubs)
        v.client.establish_proxies(net)
    net.run_until(5.0)  # let establishment + acks settle
    return Overlay(net, users, models, verifiers, registry, committee, cfg)
