"""Verification node: committee member that challenges model nodes through
the anonymous overlay (§3.4).

Each verification node owns (a) a local copy of the served LLM for scoring
(core/verification.py), (b) an anonymous client (a UserNode) so its
challenge prompts are indistinguishable from user traffic, and (c) a seat
in the VerificationCommittee (core/consensus.py).
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Callable

from repro.core import ed25519
from repro.core.consensus import Challenge, SignedResponse
from repro.overlay.user_node import UserNode


@dataclass
class ChallengeOutcome:
    model_node: object
    prompt: tuple
    response: tuple
    signature: bytes = b""
    received: bool = False


class VerificationNode:
    def __init__(self, node_id, score_fn: Callable, rng=None,
                 use_crypto: bool = True):
        self.node_id = node_id
        self.score_fn = score_fn            # pairs -> C in [0,1]
        self.key = ed25519.SigningKey() if use_crypto else None
        self.client = UserNode(f"{node_id}:anon", rng=rng,
                               use_crypto=use_crypto)
        self.rng = rng or random.Random(0)
        self._outcomes: dict = {}

    # the anonymous client doubles as this node's network presence
    def on_message(self, net, src, msg):
        self.client.on_message(net, src, msg)

    def send_challenges(self, net, challenges: list[Challenge],
                        max_new: int = 16):
        """Leader duty: fire the agreed challenge prompts through the
        anonymous overlay, collect responses via the client callback."""
        self._outcomes = {
            c.model_node: ChallengeOutcome(c.model_node, c.prompt, ())
            for c in challenges}

        def on_resp(_net, payload):
            node = payload["server"]
            oc = self._outcomes.get(node)
            if oc is not None and tuple(payload["prompt"]) == oc.prompt:
                oc.response = tuple(payload["output"])
                oc.received = True

        self.client.on_response = on_resp
        for c in challenges:
            self.client.send_prompt(net, list(c.prompt),
                                    model_id=c.model_node,
                                    extra_meta={"max_new": max_new})

    def collect(self) -> list[SignedResponse]:
        out = []
        for oc in self._outcomes.values():
            if oc.received:
                out.append(SignedResponse(oc.model_node, oc.prompt,
                                          oc.response, oc.signature, True))
        return out

    def missing(self) -> list:
        return [oc.model_node for oc in self._outcomes.values()
                if not oc.received]
