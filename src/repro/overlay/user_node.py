"""User node: onion proxy establishment + S-IDA clove messaging + relay
duty + session affinity (§3.2, Figs 2-4).

Every user node is also a relay for others (RelayState).  Data-path
messages carry only a path_id — no public-key crypto on relays.
"""
from __future__ import annotations

import itertools
import os
import random
from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.core import ed25519, onion, sida

PATH_LEN = 3          # Tor-calibrated 3 hops (paper §3.2)


@dataclass
class ProxyPath:
    path_id: bytes
    first_hop: object
    proxy_id: object
    relays: tuple = ()
    established: bool = False


@dataclass
class PendingMsg:
    cloves: dict = field(default_factory=dict)
    meta: dict = field(default_factory=dict)
    done: bool = False


class UserNode:
    def __init__(self, node_id, rng: Optional[random.Random] = None,
                 n_proxies: int = 4, sida_n: int = 4, sida_k: int = 3,
                 use_crypto: bool = True):
        self.node_id = node_id
        self.rng = rng or random.Random(hash(node_id) & 0xFFFF)
        self.n_proxies = n_proxies
        self.sida_n = sida_n
        self.sida_k = sida_k
        self.use_crypto = use_crypto
        if use_crypto:
            self.sign_key = ed25519.SigningKey()
            self.dh_sk, self.dh_pub = ed25519.dh_keypair()
        else:  # fast mode for large simulations: identity still unique
            self.sign_key = None
            self.dh_sk = self.dh_pub = os.urandom(32)
        self.relay = onion.RelayState()
        self.paths: list[ProxyPath] = []
        self.user_list: list = []         # NodeRecord of peers
        self.model_list: list = []
        self._inbox: dict = {}            # msg_id -> PendingMsg
        self._msg_ids = itertools.count()
        self.sessions: dict = {}          # session -> model node id
        # client-side prefix affinity: leading-block chain digest of a
        # served prompt -> the model node that served it.  Re-sending a
        # prompt that shares its first BLOCK goes straight to the likely
        # prefix holder, saving the forward hop the group-side sketch
        # routing would otherwise spend re-routing it.
        self._prefix_servers: "dict[bytes, object]" = {}
        self.prefix_affinity_cap = 64     # LRU bound on remembered digests
        self.on_response: Optional[Callable] = None
        self.stats = {"sent": 0, "recovered": 0, "failed": 0}

    # ------------------------------------------------------------------
    # bootstrap
    # ------------------------------------------------------------------
    def load_lists(self, user_list, model_list, committee_pubs=None):
        if committee_pubs is not None and self.use_crypto:
            assert user_list.verify(committee_pubs), "bad user list signature"
            assert model_list.verify(committee_pubs), "bad model list sig"
        self.user_list = list(user_list.records)
        self.model_list = list(model_list.records)

    def establish_proxies(self, net, n: Optional[int] = None):
        """Build N proxies over 3-hop onion paths (Fig 2)."""
        want = n or self.n_proxies
        peers = [r for r in self.user_list if r.node_id != self.node_id]
        used: set = set()
        for _ in range(want):
            if len(peers) < PATH_LEN:
                break
            # relay-disjoint paths while the pool allows: one relay failure
            # must cost at most one path (path-diversity requirement 4)
            avail = [r for r in peers if r.node_id not in used]
            pool = avail if len(avail) >= PATH_LEN else peers
            hops = self.rng.sample(pool, PATH_LEN)
            used.update(r.node_id for r in hops)
            if self.use_crypto:
                hop_keys = [(r.node_id, r.dh_pub) for r in hops]
                pid, first, blob = onion.build_establishment(
                    self.node_id, self.dh_pub, hop_keys)
                msg = {"type": "onion_create", "blob": blob}
            else:  # plaintext establishment for scale sims (same topology)
                pid = os.urandom(16)
                chain = [r.node_id for r in hops]
                msg = {"type": "onion_create_fast", "path_id": pid,
                       "chain": chain, "origin": self.node_id, "hop": 0}
                first = chain[0]
            self.paths.append(ProxyPath(pid, first, hops[-1].node_id,
                                        tuple(r.node_id for r in hops)))
            net.send(self.node_id, first, msg, size_bytes=512)

    def live_paths(self) -> list:
        return [p for p in self.paths if p.established]

    def maintain(self, net):
        """Proxy refresh (paper §5.2: re-discover proxies periodically).
        Drops paths through nodes known dead, tops back up to n_proxies."""
        self.paths = [p for p in self.paths
                      if all(net.alive(r) for r in p.relays)]
        missing = self.n_proxies - len(self.live_paths())
        if missing > 0:
            self.establish_proxies(net, n=missing)

    # ------------------------------------------------------------------
    # sending prompts (Fig 3)
    # ------------------------------------------------------------------
    def send_prompt(self, net, prompt_tokens, llm: str = "",
                    session: Optional[str] = None,
                    model_id=None, extra_meta: Optional[dict] = None):
        paths = self.live_paths()
        if len(paths) < self.sida_n:
            self.stats["failed"] += 1
            return None
        chosen = self._pick_disjoint(paths, self.sida_n)
        if model_id is None:
            if session is not None and session in self.sessions:
                model_id = self.sessions[session]   # session affinity
            else:
                model_id = self._affinity_entry(prompt_tokens, llm)
            if model_id is None:
                cands = [r for r in self.model_list
                         if (not llm or r.llm == llm)]
                model_id = self.rng.choice(cands).node_id
        msg_id = f"{self.node_id}:{next(self._msg_ids)}"
        payload = {
            "prompt": list(map(int, prompt_tokens)),
            "msg_id": msg_id,
            "session": session,
            "llm": llm,
            # reply routing: proxy ids + path ids (revealed only to the
            # model node once it holds >= k cloves)
            "reply": [(p.proxy_id, p.path_id.hex()) for p in chosen],
        }
        if extra_meta:
            payload.update(extra_meta)
        blob = _encode(payload)
        cloves = sida.make_cloves(blob, self.sida_n, self.sida_k)
        # random bucket key so concurrent requests at a model node cannot
        # mix cloves; carries no sender identity
        msg_key = os.urandom(8).hex()
        for p, c in zip(chosen, cloves):
            net.send(self.node_id, _route_next(self, p.path_id),
                     {"type": "clove_fwd", "path_id": p.path_id.hex(),
                      "dest_model": model_id, "clove": c.encode(),
                      "msg_key": msg_key, "dir": "out"},
                     size_bytes=len(c.frag) + 128)
        self.stats["sent"] += 1
        return msg_id

    def _affinity_entry(self, tokens, llm: str):
        """Entry node remembered for this prompt's leading block, if it is
        still in the model list (None -> caller falls back to random)."""
        dg = _leading_digest(tokens)
        target = self._prefix_servers.get(dg) if dg else None
        if target is None:
            return None
        if any(r.node_id == target and (not llm or r.llm == llm)
               for r in self.model_list):
            return target
        self._prefix_servers.pop(dg, None)       # server left the overlay
        return None

    def _learn_prefix_server(self, payload: dict):
        dg = _leading_digest(payload.get("prompt") or [])
        if dg is None or payload.get("server") is None:
            return
        self._prefix_servers.pop(dg, None)       # refresh LRU position
        self._prefix_servers[dg] = payload["server"]
        while len(self._prefix_servers) > self.prefix_affinity_cap:
            self._prefix_servers.pop(next(iter(self._prefix_servers)))

    def _pick_disjoint(self, paths: list, n: int) -> list:
        """Greedy relay-disjoint path selection: a single relay failure
        should cost at most one clove (the point of path diversity)."""
        order = self.rng.sample(paths, len(paths))
        chosen, used = [], set()
        for p in order:
            if not (set(p.relays) & used):
                chosen.append(p)
                used |= set(p.relays)
            if len(chosen) == n:
                return chosen
        for p in order:  # fill remaining slots even if overlapping
            if p not in chosen:
                chosen.append(p)
            if len(chosen) == n:
                break
        return chosen

    # ------------------------------------------------------------------
    # message handling (relay + endpoint duties)
    # ------------------------------------------------------------------
    def on_message(self, net, src, msg):
        mt = msg["type"]
        if mt == "onion_create":
            self._handle_onion_create(net, src, msg)
        elif mt == "onion_create_fast":
            self._handle_onion_create_fast(net, src, msg)
        elif mt == "proxy_ack":
            for p in self.paths:
                if p.path_id.hex() == msg["path_id"]:
                    p.established = True
        elif mt == "clove_fwd":
            self._relay_clove(net, src, msg)
        elif mt == "response_clove":
            self._handle_response_clove(net, src, msg)

    def _handle_onion_create(self, net, src, msg):
        try:
            pid, pred, succ, inner, payload = onion.peel_establishment(
                msg["blob"], self.dh_sk)
        except Exception:
            return
        self.relay.install(pid, pred, succ)
        if succ is None:
            # we are the proxy: ack travels the reverse path
            net.send(self.node_id, pred,
                     {"type": "response_clove", "path_id": pid.hex(),
                      "ack": True}, 64)
        else:
            net.send(self.node_id, succ, {"type": "onion_create",
                                          "blob": inner}, len(inner))

    def _handle_onion_create_fast(self, net, src, msg):
        pid = msg["path_id"]
        chain = msg["chain"]
        hop = msg["hop"]
        pred = msg["origin"] if hop == 0 else chain[hop - 1]
        succ = chain[hop + 1] if hop + 1 < len(chain) else None
        self.relay.install(pid, pred, succ)
        if succ is None:
            net.send(self.node_id, pred,
                     {"type": "response_clove", "path_id": pid.hex(),
                      "ack": True}, 64)
        else:
            net.send(self.node_id, succ, {**msg, "hop": hop + 1}, 256)

    def _relay_clove(self, net, src, msg):
        pid = bytes.fromhex(msg["path_id"])
        nxt = self.relay.next_hop(pid, src)
        if nxt is None:
            # we are the proxy for this path: hand to the model node
            net.send(self.node_id, msg["dest_model"],
                     {"type": "prompt_clove", "clove": msg["clove"],
                      "msg_key": msg.get("msg_key"),
                      "proxy": self.node_id},
                     size_bytes=len(msg["clove"]) + 64)
        else:
            net.send(self.node_id, nxt, msg,
                     size_bytes=len(msg["clove"]) + 64)

    def _handle_response_clove(self, net, src, msg):
        pid = bytes.fromhex(msg["path_id"])
        if msg.get("ack"):
            route = self.relay.next_hop(pid, src)
            if route is not None:
                net.send(self.node_id, route, msg, 64)
                return
            for p in self.paths:
                if p.path_id == pid:
                    p.established = True
            return
        nxt = self.relay.next_hop(pid, src)
        if nxt is not None and not any(p.path_id == pid for p in self.paths):
            net.send(self.node_id, nxt, msg,
                     size_bytes=len(msg["clove"]) + 64)
            return
        # we are the requesting user: collect cloves
        clove = sida.Clove.decode(msg["clove"])
        msg_id = msg["msg_id"]
        pend = self._inbox.setdefault(msg_id, PendingMsg())
        if pend.done:
            return
        pend.cloves[clove.index] = clove
        if len(pend.cloves) >= clove.k:
            try:
                blob = sida.recover(list(pend.cloves.values()))
            except Exception:
                return
            pend.done = True
            payload = _decode(blob)
            self.stats["recovered"] += 1
            if payload.get("session"):
                self.sessions[payload["session"]] = payload["server"]
            self._learn_prefix_server(payload)
            if self.on_response:
                self.on_response(net, payload)


def _leading_digest(tokens):
    """Chain digest of the first BLOCK of ``tokens`` (None if shorter) —
    the key under which a user remembers which model node served a
    prompt family.  Same digest function the serving caches index by;
    only the first block is hashed, since deeper digests are unused."""
    from repro.serving.prefix_cache import BLOCK, _chain_hashes
    h = _chain_hashes(tokens[:BLOCK])
    return h[0] if h else None


def _route_next(user: "UserNode", path_id: bytes):
    nxt = user.relay.next_hop(path_id, None)
    if nxt is not None:
        return nxt
    for p in user.paths:
        if p.path_id == path_id:
            return p.first_hop
    raise KeyError("unknown path")


def _encode(obj) -> bytes:
    import msgpack
    return msgpack.packb(obj, use_bin_type=True)


def _decode(blob: bytes):
    import msgpack
    return msgpack.unpackb(blob, raw=False)
