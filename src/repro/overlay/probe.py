"""Minimal overlay endpoints for benches and tests.

``ResponseSink`` stands in for the user's proxy path when driving model
nodes directly on a SimNet: register it under a node id, point request
payloads' ``reply`` route at it, and read recovered outputs by msg_id.
Shared by benchmarks/bench_affinity.py and tests/test_affinity_serving.py
so the response-clove decode and payload shape live in one place.
"""
from __future__ import annotations

from repro.core import sida
from repro.overlay.user_node import _decode


class ResponseSink:
    """Collects single-clove responses (n=1, k=1 S-IDA) by msg_id."""

    def __init__(self):
        self.got = {}

    def on_message(self, net, src, msg):
        payload = _decode(sida.recover([sida.Clove.decode(msg["clove"])]))
        self.got[payload["msg_id"]] = payload["output"]


def direct_payload(msg_id, toks, max_new: int = 4,
                   sink_id="sink") -> dict:
    """Request payload for ModelNode._process with replies routed to a
    ``ResponseSink`` registered as ``sink_id`` (single reply path -> the
    model node emits one k=1 clove straight to the sink)."""
    return {"prompt": list(toks), "msg_id": msg_id, "session": None,
            "max_new": max_new, "reply": [(sink_id, "00")]}
