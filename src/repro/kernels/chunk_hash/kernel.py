"""HR-tree chunk hashing as a Pallas kernel.

Model nodes hash every incoming prompt into chunk fingerprints (core/
hrtree.preprocess) — at production rates (thousands of ~10k-token prompts
per second per group) this is a measurable CPU hot spot the paper's model
nodes pay on every request.  On TPU the polynomial rolling hash

    h_{i+1} = h_i * M + t_i + 1   (mod 2^32)

over a fixed chunk width W becomes a log-step scan: precompute M^(2^j)
and do W -> W/2 pair reductions on the VPU (u32 lane ops), hashing every
chunk of every request in one launch.  The xor-fold to b bits matches
core/hrtree.chunk_hash exactly for fixed-width chunks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

MULT = 1_000_003
SEED = 0x9E3779B9
M32 = 1 << 32


def _hash_kernel(t_ref, o_ref, *, width, bits):
    toks = t_ref[0].astype(jnp.uint32)                 # (nchunks, width)
    vals = toks + np.uint32(1)
    # log-step pairwise combine: [a, b] -> a * M^(len_b) + b
    # multiplier powers are static Python ints (mod 2^32) -> inline literals
    w, level = width, 0
    while w > 1:
        m = np.uint32(pow(MULT, 1 << level, M32))
        vals = vals[:, 0::2] * m + vals[:, 1::2]
        w //= 2
        level += 1
    # fold in the seed: h = SEED * M^width + poly
    seed_term = np.uint32((SEED * pow(MULT, width, M32)) % M32)
    h = seed_term + vals[:, 0]
    # xor-fold 32 -> bits
    out = jnp.zeros_like(h)
    x = h
    for _ in range(32 // bits + 1):
        out = out ^ (x & np.uint32((1 << bits) - 1))
        x = x >> np.uint32(bits)
    o_ref[0] = out.astype(jnp.uint32)


def chunk_hash_pallas(tokens, *, width=64, bits=8, interpret=False):
    """tokens: (B, S) int32, S % width == 0 -> (B, S // width) uint32."""
    B, S = tokens.shape
    assert S % width == 0 and width & (width - 1) == 0, \
        "width must be a power of two dividing S"
    n = S // width
    kern = functools.partial(_hash_kernel, width=width, bits=bits)
    return pl.pallas_call(
        kern,
        grid=(B,),
        in_specs=[pl.BlockSpec((1, n, width), lambda b: (b, 0, 0))],
        out_specs=pl.BlockSpec((1, n), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, n), jnp.uint32),
        interpret=interpret,
    )(tokens.reshape(B, n, width))
