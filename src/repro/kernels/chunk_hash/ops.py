"""jit'd wrapper for batched fixed-width chunk hashing."""
from __future__ import annotations

import functools

import jax

from repro.kernels.chunk_hash.kernel import chunk_hash_pallas


@functools.partial(jax.jit, static_argnames=("width", "bits", "impl"))
def chunk_hash_fixed(tokens, *, width=64, bits=8, impl="auto"):
    """tokens: (B, S) int32 -> (B, S // width) uint32 chunk fingerprints."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "interpret"
    return chunk_hash_pallas(tokens, width=width, bits=bits,
                             interpret=impl == "interpret")
