"""Oracle: fixed-width chunk hashing must equal core/hrtree.chunk_hash."""
from __future__ import annotations

import numpy as np

from repro.core.hrtree import chunk_hash


def chunk_hash_ref(tokens: np.ndarray, *, width=64, bits=8) -> np.ndarray:
    B, S = tokens.shape
    n = S // width
    out = np.zeros((B, n), np.uint32)
    for b in range(B):
        for c in range(n):
            out[b, c] = chunk_hash(tokens[b, c * width:(c + 1) * width],
                                   bits=bits)
    return out
