from repro.kernels.chunk_hash.ops import chunk_hash_fixed  # noqa: F401
