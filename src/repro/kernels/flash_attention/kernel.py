"""Flash attention Pallas TPU kernel: blockwise online softmax.

Layout (B, H, S, D).  Grid = (B*H, S/bq): one program owns one query block
for one (batch, head); K/V for the matching KV head stay VMEM-resident per
program and are walked in bk-sized blocks with the online-softmax (m, l,
acc) recurrence — the classic flash schedule, MXU-shaped (bq x bk x D
matmuls), with causal masking, sliding windows, logit softcap and GQA
(KV-head indexing in the BlockSpec index_map, no KV repetition in HBM).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, causal, window,
                 softcap, bq, bk, seq_kv):
    iq = pl.program_id(1)
    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, D)
    D = q.shape[-1]
    m = jnp.full((bq,), NEG_INF, jnp.float32)
    lsum = jnp.zeros((bq,), jnp.float32)
    acc = jnp.zeros((bq, D), jnp.float32)
    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)

    nk = seq_kv // bk

    def body(j, carry):
        m, lsum, acc = carry
        k = k_ref[0, 0, pl.dslice(j * bk, bk), :].astype(jnp.float32)
        v = v_ref[0, 0, pl.dslice(j * bk, bk), :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = lsum * alpha + p.sum(axis=1)
        acc_new = acc * alpha[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ()))).astype(jnp.float32)
        return m_new, l_new, acc_new

    # causal: skip key blocks strictly after this query block
    if causal:
        nk_eff = jnp.minimum(nk, ((iq + 1) * bq + bk - 1) // bk)
    else:
        nk_eff = nk
    m, lsum, acc = jax.lax.fori_loop(0, nk_eff, body, (m, lsum, acc))
    out = acc / jnp.maximum(lsum, 1e-20)[:, None]
    o_ref[0, 0] = out.astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal=True, window=None,
                           softcap=None, scale=None, bq=128, bk=128,
                           interpret=False):
    """q: (B, H, S, D); k, v: (B, Hkv, S, D) -> (B, H, S, D)."""
    B, H, S, D = q.shape
    Hkv = k.shape[1]
    Skv = k.shape[2]
    group = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    bq = min(bq, S)
    bk = min(bk, Skv)
    assert S % bq == 0 and Skv % bk == 0

    kern = functools.partial(_attn_kernel, scale=scale, causal=causal,
                             window=window, softcap=softcap, bq=bq, bk=bk,
                             seq_kv=Skv)
    grid = (B * H, S // bq)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D),
                         lambda bh, iq: (bh // H, bh % H, iq, 0)),
            pl.BlockSpec((1, 1, Skv, D),
                         lambda bh, iq: (bh // H, (bh % H) // group, 0, 0)),
            pl.BlockSpec((1, 1, Skv, D),
                         lambda bh, iq: (bh // H, (bh % H) // group, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D),
                               lambda bh, iq: (bh // H, bh % H, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        interpret=interpret,
    )(q, k, v)
