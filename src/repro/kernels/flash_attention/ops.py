"""jit'd public wrapper: picks the Pallas kernel on TPU, interpret-mode
kernel for CPU validation, or the jnp oracle."""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "scale", "bq", "bk", "impl"))
def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    scale=None, bq=128, bk=128, impl="auto"):
    """impl: auto | pallas | interpret | ref"""
    if impl == "auto":
        impl = ("pallas" if jax.default_backend() == "tpu" else "ref")
    if impl == "ref":
        return attention_ref(q, k, v, causal=causal, window=window,
                             softcap=softcap, scale=scale)
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  softcap=softcap, scale=scale, bq=bq, bk=bk,
                                  interpret=impl == "interpret")
