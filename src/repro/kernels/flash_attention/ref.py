"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True, window=None, softcap=None,
                  scale=None):
    """q: (B, H, S, D); k, v: (B, Hkv, S, D) -> (B, H, S, D)."""
    B, H, S, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    group = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(Skv)[None, :]
    mask = jnp.ones((S, Skv), bool)
    if causal:
        mask &= kp <= qp
    if window is not None:
        mask &= (qp - kp) < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      vr.astype(jnp.float32)).astype(q.dtype)
