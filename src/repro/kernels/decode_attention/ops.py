"""jit'd public wrapper for decode attention."""
from __future__ import annotations

import functools

import jax

from repro.kernels.decode_attention.kernel import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref


@functools.partial(jax.jit, static_argnames=("window", "softcap", "scale",
                                             "n_splits", "impl"))
def decode_attention(q, k, v, lengths, *, window=None, softcap=None,
                     scale=None, n_splits=8, impl="auto"):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return decode_attention_ref(q, k, v, lengths, window=window,
                                    softcap=softcap, scale=scale)
    return decode_attention_pallas(q, k, v, lengths, window=window,
                                   softcap=softcap, scale=scale,
                                   n_splits=n_splits,
                                   interpret=impl == "interpret")
