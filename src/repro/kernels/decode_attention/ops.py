"""jit'd public wrapper for decode attention."""
from __future__ import annotations

import functools

import jax

from repro.kernels.decode_attention.kernel import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref


@functools.partial(jax.jit, static_argnames=("window", "softcap", "scale",
                                             "n_splits", "impl"))
def decode_attention(q, k, v, lengths, *, window=None, softcap=None,
                     scale=None, n_splits=8, impl="auto"):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return decode_attention_ref(q, k, v, lengths, window=window,
                                    softcap=softcap, scale=scale)
    return decode_attention_pallas(q, k, v, lengths, window=window,
                                   softcap=softcap, scale=scale,
                                   n_splits=n_splits,
                                   interpret=impl == "interpret")


@functools.partial(jax.jit, static_argnames=("window", "softcap", "scale",
                                             "impl"))
def paged_decode_attention(q, k_arena, v_arena, page_table, lengths, *,
                           window=None, softcap=None, scale=None,
                           impl="auto"):
    """Paged split-K decode: q (B, H, D); arenas (P, BLOCK, Hkv, D);
    page_table (B, n_pg); lengths (B,).  The pallas path gathers pages
    inside the kernel via a scalar-prefetched page table."""
    from repro.kernels.decode_attention.paged import \
        paged_decode_attention_pallas
    from repro.kernels.decode_attention.ref import paged_decode_attention_ref
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return paged_decode_attention_ref(q, k_arena, v_arena, page_table,
                                          lengths, window=window,
                                          softcap=softcap, scale=scale)
    return paged_decode_attention_pallas(q, k_arena, v_arena, page_table,
                                         lengths, window=window,
                                         softcap=softcap, scale=scale,
                                         interpret=impl == "interpret")
