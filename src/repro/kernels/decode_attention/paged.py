"""Paged decode attention: split-K flash-decoding over physical KV pages.

The dense split-K kernel (kernel.py) keeps the page indirection at the XLA
level; THIS variant moves it inside the kernel the way vLLM's
PagedAttention does, TPU-style: the per-request page table rides in as a
**scalar-prefetch** operand (pltpu.PrefetchScalarGridSpec), so the BlockSpec
index map can pick each grid step's KV tile straight out of the arena —
grid = (B*H, n_pages); program (bh, j) DMAs physical page
``page_table[b, j]`` and reduces it to a partial (m, l, acc).  The cheap
cross-page softmax combine runs at the XLA level, identical to the dense
kernel's cross-split combine.

Arena layout is the serving layout ``(num_pages, BLOCK, n_kv, D)``
(models/lm.py ``paged_arena_zeros``); the wrapper transposes to the
VMEM-friendly ``(num_pages, n_kv, BLOCK, D)`` tiling at the XLA level.
Logical slot ``j * BLOCK + t`` holds absolute position ``j * BLOCK + t``,
so one per-request valid length masks the unwritten tail of the last page
and every unallocated table entry at once.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref, m_ref,
                  l_ref, *, scale, softcap, blk, window):
    j = pl.program_id(1)                                   # logical page
    q = q_ref[0].astype(jnp.float32) * scale               # (1, D)
    k = k_ref[0, 0].astype(jnp.float32)                    # (blk, D)
    v = v_ref[0, 0].astype(jnp.float32)
    valid_len = len_ref[0, 0]                              # scalar int32
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (1, blk)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    pos = j * blk + jax.lax.broadcasted_iota(jnp.int32, (1, blk), 1)
    mask = pos < valid_len
    if window is not None:
        mask &= pos >= (valid_len - window)
    s = jnp.where(mask, s, NEG_INF)
    m = s.max()
    p = jnp.exp(s - m)
    lsum = p.sum()
    acc = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))  # (1, D)
    o_ref[0, 0] = acc.astype(o_ref.dtype)
    m_ref[0, 0, 0] = m
    l_ref[0, 0, 0] = lsum


def paged_decode_attention_pallas(q, k_arena, v_arena, page_table, lengths,
                                  *, window=None, softcap=None, scale=None,
                                  interpret=False):
    """q: (B, H, D); arenas: (P, BLOCK, Hkv, D); page_table: (B, n_pg)
    physical page per logical block; lengths: (B,) valid tokens (0 for a
    masked slot-pool row — its partials are uniform garbage the caller
    discards).  Returns (B, H, D)."""
    B, H, D = q.shape
    P, blk, Hkv, _ = k_arena.shape
    group = H // Hkv
    n_pg = page_table.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    ka = k_arena.transpose(0, 2, 1, 3)                     # (P, Hkv, blk, D)
    va = v_arena.transpose(0, 2, 1, 3)

    kern = functools.partial(_paged_kernel, scale=scale, softcap=softcap,
                             blk=blk, window=window)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                             # the page table
        grid=(B * H, n_pg),
        in_specs=[
            pl.BlockSpec((1, 1), lambda bh, j, pt: (bh // H, 0)),
            pl.BlockSpec((1, 1, D), lambda bh, j, pt: (bh // H, bh % H, 0)),
            pl.BlockSpec((1, 1, blk, D),
                         lambda bh, j, pt: (pt[bh // H, j],
                                            (bh % H) // group, 0, 0)),
            pl.BlockSpec((1, 1, blk, D),
                         lambda bh, j, pt: (pt[bh // H, j],
                                            (bh % H) // group, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, D),
                         lambda bh, j, pt: (bh // H, bh % H, j, 0)),
            pl.BlockSpec((1, 1, 1), lambda bh, j, pt: (bh // H, bh % H, j)),
            pl.BlockSpec((1, 1, 1), lambda bh, j, pt: (bh // H, bh % H, j)),
        ],
    )
    out, ms, ls = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, H, n_pg, D), jnp.float32),
            jax.ShapeDtypeStruct((B, H, n_pg), jnp.float32),
            jax.ShapeDtypeStruct((B, H, n_pg), jnp.float32),
        ],
        interpret=interpret,
    )(page_table.astype(jnp.int32),
      lengths.reshape(B, 1).astype(jnp.int32), q, ka, va)

    # cross-page combine (cheap, XLA level) — same as the dense kernel
    m_all = ms.max(axis=-1, keepdims=True)                 # (B, H, 1)
    w = jnp.exp(ms - m_all)                                # (B, H, n_pg)
    l_tot = (ls * w).sum(-1)                               # (B, H)
    o = (out * w[..., None]).sum(2) / jnp.maximum(l_tot, 1e-20)[..., None]
    return o.astype(q.dtype)
