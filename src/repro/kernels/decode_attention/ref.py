"""Pure-jnp oracle for split-K decode attention."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def decode_attention_ref(q, k, v, lengths, *, window=None, softcap=None,
                         scale=None):
    """q: (B, H, D); k, v: (B, Hkv, S, D); lengths: (B,) -> (B, H, D)."""
    B, H, D = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    group = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    pos = jnp.arange(S)[None, None]
    mask = pos < lengths[:, None, None]
    if window is not None:
        mask &= pos >= (lengths[:, None, None] - window)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhk,bhkd->bhd", p,
                      vr.astype(jnp.float32)).astype(q.dtype)
