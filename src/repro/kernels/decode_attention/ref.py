"""Pure-jnp oracle for split-K decode attention."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def decode_attention_ref(q, k, v, lengths, *, window=None, softcap=None,
                         scale=None):
    """q: (B, H, D); k, v: (B, Hkv, S, D); lengths: (B,) -> (B, H, D)."""
    B, H, D = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    group = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    pos = jnp.arange(S)[None, None]
    mask = pos < lengths[:, None, None]
    if window is not None:
        mask &= pos >= (lengths[:, None, None] - window)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhk,bhkd->bhd", p,
                      vr.astype(jnp.float32)).astype(q.dtype)


def paged_decode_attention_ref(q, k_arena, v_arena, page_table, lengths, *,
                               window=None, softcap=None, scale=None):
    """Oracle for the paged kernel: gather pages at the XLA level into the
    dense per-request layout, then run the dense oracle.  q: (B, H, D);
    arenas: (P, BLOCK, Hkv, D); page_table: (B, n_pg); lengths: (B,)."""
    B = q.shape[0]
    blk = k_arena.shape[1]
    n_pg = page_table.shape[1]

    def dense(arena):
        g = jnp.take(arena, page_table.reshape(-1), axis=0)
        g = g.reshape(B, n_pg * blk, *arena.shape[2:])     # (B, S, Hkv, D)
        return g.transpose(0, 2, 1, 3)                     # (B, Hkv, S, D)

    return decode_attention_ref(q, dense(k_arena), dense(v_arena), lengths,
                                window=window, softcap=softcap, scale=scale)
