"""Decode attention (one query token vs a long KV cache): split-K
flash-decoding, TPU-adapted.

vLLM's PagedAttention gathers KV pages via a page table inside the CUDA
kernel; TPU VMEM wants dense tiles, so the page indirection happens at the
XLA level (dense cache slabs) and THIS kernel parallelizes over cache
splits instead: grid = (B*H, n_splits); each program reduces its KV span
to a partial (m, l, acc) written to HBM; the cheap cross-split softmax
combine runs in ops.py.  Per-request valid lengths mask the tail.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *,
                   scale, softcap, split, window):
    js = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale               # (1, D)
    k = k_ref[0, 0].astype(jnp.float32)                    # (split, D)
    v = v_ref[0, 0].astype(jnp.float32)
    valid_len = len_ref[0, 0]                              # scalar int32
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (1, split)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    pos = js * split + jax.lax.broadcasted_iota(jnp.int32, (1, split), 1)
    mask = pos < valid_len
    if window is not None:
        mask &= pos >= (valid_len - window)
    s = jnp.where(mask, s, NEG_INF)
    m = s.max()
    p = jnp.exp(s - m)
    lsum = p.sum()
    acc = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))  # (1, D)
    o_ref[0, 0] = acc.astype(o_ref.dtype)
    m_ref[0, 0, 0] = m
    l_ref[0, 0, 0] = lsum


def decode_attention_pallas(q, k, v, lengths, *, window=None, softcap=None,
                            scale=None, n_splits=8, interpret=False):
    """q: (B, H, D); k, v: (B, Hkv, S, D); lengths: (B,) valid KV length.

    Returns (B, H, D)."""
    B, H, D = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    group = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    n_splits = max(1, min(n_splits, S))
    while S % n_splits:
        n_splits -= 1
    split = S // n_splits

    kern = functools.partial(_decode_kernel, scale=scale, softcap=softcap,
                             split=split, window=window)
    grid = (B * H, n_splits)
    out, ms, ls = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda bh, js: (bh // H, 0)),
            pl.BlockSpec((1, 1, D), lambda bh, js: (bh // H, bh % H, 0)),
            pl.BlockSpec((1, 1, split, D),
                         lambda bh, js: (bh // H, (bh % H) // group, js, 0)),
            pl.BlockSpec((1, 1, split, D),
                         lambda bh, js: (bh // H, (bh % H) // group, js, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, D),
                         lambda bh, js: (bh // H, bh % H, js, 0)),
            pl.BlockSpec((1, 1, 1),
                         lambda bh, js: (bh // H, bh % H, js)),
            pl.BlockSpec((1, 1, 1),
                         lambda bh, js: (bh // H, bh % H, js)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, n_splits, D), jnp.float32),
            jax.ShapeDtypeStruct((B, H, n_splits), jnp.float32),
            jax.ShapeDtypeStruct((B, H, n_splits), jnp.float32),
        ],
        interpret=interpret,
    )(lengths.reshape(B, 1).astype(jnp.int32), q, k, v)

    # cross-split combine (cheap, XLA level)
    m_all = ms.max(axis=-1, keepdims=True)                 # (B,H,1)
    w = jnp.exp(ms - m_all)                                # (B,H,ns)
    l_tot = (ls * w).sum(-1)                               # (B,H)
    o = (out * w[..., None]).sum(2) / jnp.maximum(l_tot, 1e-20)[..., None]
    return o.astype(q.dtype)
