"""Oracle: sequential (per-timestep) SSD recurrence in pure jnp.

  h_t = exp(loga_t) * h_{t-1} + B_t xbar_t^T ;  y_t = C_t . h_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mamba_scan_ref(xbar, loga, Bm, Cm, h0=None):
    """xbar: (B, H, C, L, P); loga: (B, H, C, L); Bm/Cm: (B, C, L, N)."""
    B, H, C, L, P = xbar.shape
    N = Bm.shape[-1]
    S = C * L
    xs = xbar.reshape(B, H, S, P).astype(jnp.float32)
    la = loga.reshape(B, H, S).astype(jnp.float32)
    bm = Bm.reshape(B, S, N).astype(jnp.float32)
    cm = Cm.reshape(B, S, N).astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((B, H, N, P), jnp.float32)

    def step(h, t):
        a = jnp.exp(la[:, :, t])                     # (B, H)
        hb = jnp.einsum("bn,bhp->bhnp", bm[:, t], xs[:, :, t])
        h = h * a[:, :, None, None] + hb
        y = jnp.einsum("bn,bhnp->bhp", cm[:, t], h)
        return h, y

    h_fin, ys = jax.lax.scan(step, h0, jnp.arange(S))
    y = jnp.moveaxis(ys, 0, 2).reshape(B, H, C, L, P)
    return y.astype(xbar.dtype), h_fin
