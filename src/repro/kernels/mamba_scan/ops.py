"""jit'd wrapper for the chunked SSD scan."""
from __future__ import annotations

import functools

import jax

from repro.kernels.mamba_scan.kernel import mamba_scan_pallas
from repro.kernels.mamba_scan.ref import mamba_scan_ref


@functools.partial(jax.jit, static_argnames=("impl",))
def mamba_scan(xbar, loga, Bm, Cm, h0=None, *, impl="auto"):
    """xbar: (B,H,C,L,P); loga: (B,H,C,L); Bm/Cm: (B,C,L,N) ->
    (y (B,H,C,L,P), h_fin (B,H,N,P))."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return mamba_scan_ref(xbar, loga, Bm, Cm, h0)
    return mamba_scan_pallas(xbar, loga, Bm, Cm, h0,
                             interpret=impl == "interpret")
