"""Chunked SSD selective-scan Pallas kernel (Mamba, TPU-native form).

Grid = (B*H, n_chunks) with the chunk axis SEQUENTIAL ("arbitrary"
dimension semantics on TPU): each program computes one chunk's
intra-chunk quadratic form on the MXU and carries the (N, P) SSM state to
the next chunk through a state output ref whose block index is constant
along the chunk axis (the canonical Pallas carry pattern).  The state is
initialized from h0 at chunk 0 (cache continuation works).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mamba_kernel(xb_ref, la_ref, bm_ref, cm_ref, h0_ref,
                  y_ref, h_ref, *, L):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        h_ref[0, 0] = h0_ref[0, 0]

    xb = xb_ref[0, 0, 0].astype(jnp.float32)         # (L, P)
    la = la_ref[0, 0, 0].astype(jnp.float32)         # (L,)
    bm = bm_ref[0, 0].astype(jnp.float32)            # (L, N)
    cm = cm_ref[0, 0].astype(jnp.float32)            # (L, N)
    h = h_ref[0, 0].astype(jnp.float32)              # (N, P)

    lcum = jnp.cumsum(la)                            # (L,)
    # inter-chunk: y_inter[s] = exp(l_s) * C_s . h
    y_inter = jax.lax.dot_general(cm, h, (((1,), (0,)), ((), ()))) \
        * jnp.exp(lcum)[:, None]                     # (L, P)
    # intra-chunk attention form
    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())))  # (L, L)
    dec = jnp.exp(lcum[:, None] - lcum[None, :])
    ii = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    att = jnp.where(jj <= ii, cb * dec, 0.0)
    y = y_inter + jax.lax.dot_general(att, xb, (((1,), (0,)), ((), ())))
    # state update: h' = exp(l_L) h + sum_t exp(l_L - l_t) B_t xbar_t^T
    w = jnp.exp(lcum[-1] - lcum)                     # (L,)
    hb = jax.lax.dot_general(bm, xb * w[:, None],
                             (((0,), (0,)), ((), ())))  # (N, P)
    h_new = jnp.exp(lcum[-1]) * h + hb
    y_ref[0, 0, 0] = y.astype(y_ref.dtype)
    h_ref[0, 0] = h_new


def mamba_scan_pallas(xbar, loga, Bm, Cm, h0=None, *, interpret=False):
    """xbar: (B, H, C, L, P); loga: (B, H, C, L); Bm/Cm: (B, C, L, N);
    h0: (B, H, N, P) f32.  Returns (y (B,H,C,L,P), h_fin (B,H,N,P))."""
    B, H, C, L, P = xbar.shape
    N = Bm.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((B, H, N, P), jnp.float32)
    kern = functools.partial(_mamba_kernel, L=L)
    grid = (B * H, C)
    y, h_fin = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, L, P),
                         lambda bh, c: (bh // H, bh % H, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, L),
                         lambda bh, c: (bh // H, bh % H, c, 0)),
            pl.BlockSpec((1, 1, L, N), lambda bh, c: (bh // H, c, 0, 0)),
            pl.BlockSpec((1, 1, L, N), lambda bh, c: (bh // H, c, 0, 0)),
            pl.BlockSpec((1, 1, N, P), lambda bh, c: (bh // H, bh % H, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, L, P),
                         lambda bh, c: (bh // H, bh % H, c, 0, 0)),
            pl.BlockSpec((1, 1, N, P), lambda bh, c: (bh // H, bh % H, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, C, L, P), xbar.dtype),
            jax.ShapeDtypeStruct((B, H, N, P), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(xbar, loga, Bm, Cm, h0)
    # squeeze the per-program singleton dims the BlockSpecs introduce
    return y.reshape(B, H, C, L, P), h_fin


def _reshape_kernel_io(x, B, H, C, L):
    return x
