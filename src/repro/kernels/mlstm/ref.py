"""Oracle: per-timestep stabilized mLSTM recurrence in pure jnp."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mlstm_ref(q, k, v, log_i, log_f, state0=None, *, scale=None):
    """q/k/v: (B, H, C, L, dh); gates: (B, H, C, L)."""
    B, H, C, L, dh = q.shape
    S = C * L
    scale = scale if scale is not None else 1.0
    qs = q.reshape(B, H, S, dh).astype(jnp.float32) * scale
    ks_ = k.reshape(B, H, S, dh).astype(jnp.float32)
    vs = v.reshape(B, H, S, dh).astype(jnp.float32)
    gi = log_i.reshape(B, H, S).astype(jnp.float32)
    gf = log_f.reshape(B, H, S).astype(jnp.float32)
    if state0 is None:
        C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
        n0 = jnp.zeros((B, H, dh), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state0

    def step(carry, t):
        Cm, n, m = carry
        m_new = jnp.maximum(gf[:, :, t] + m, gi[:, :, t])
        f_s = jnp.exp(gf[:, :, t] + m - m_new)
        i_s = jnp.exp(gi[:, :, t] - m_new)
        Cm = (f_s[:, :, None, None] * Cm
              + i_s[:, :, None, None]
              * jnp.einsum("bhe,bhf->bhef", ks_[:, :, t], vs[:, :, t]))
        n = f_s[:, :, None] * n + i_s[:, :, None] * ks_[:, :, t]
        num = jnp.einsum("bhe,bhef->bhf", qs[:, :, t], Cm)
        den = jnp.abs(jnp.einsum("bhe,bhe->bh", qs[:, :, t], n))
        h = num / jnp.maximum(den, jnp.exp(-m_new))[:, :, None]
        return (Cm, n, m_new), h

    (C_f, n_f, m_f), hs = jax.lax.scan(step, (C0, n0, m0), jnp.arange(S))
    h = jnp.moveaxis(hs, 0, 2).reshape(B, H, C, L, dh).astype(q.dtype)
    return h, (C_f, n_f, m_f)
