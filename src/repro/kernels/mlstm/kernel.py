"""Chunkwise mLSTM Pallas kernel (xLSTM matrix-memory cell).

Grid = (B*H, n_chunks), chunk axis sequential; carries the stabilized
(C~, n~, m) state across chunks through constant-indexed output refs.
Inside a chunk the exp-gate products form an (L, L) lower-triangular
matrix fused with the q.k score matmul on the MXU — the same schedule as
the SSD kernel but with data-dependent forget gates and the running-max
stabilizer (all gate math in f32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _cummax(x):
    """Inclusive running max along axis 0 via log-step doubling."""
    n = x.shape[0]
    shift = 1
    while shift < n:
        pad = jnp.full((shift,) + x.shape[1:], NEG, x.dtype)
        x = jnp.maximum(x, jnp.concatenate([pad, x[:-shift]], axis=0))
        shift *= 2
    return x


def _mlstm_kernel(q_ref, k_ref, v_ref, gi_ref, gf_ref,
                  c0_ref, n0_ref, m0_ref,
                  h_ref, c_ref, n_ref, m_ref, *, L, scale):
    c_ix = pl.program_id(1)

    @pl.when(c_ix == 0)
    def _init():
        c_ref[0, 0] = c0_ref[0, 0]
        n_ref[0, 0] = n0_ref[0, 0]
        m_ref[0, 0] = m0_ref[0, 0]

    q = q_ref[0, 0, 0].astype(jnp.float32) * scale     # (L, dh)
    k = k_ref[0, 0, 0].astype(jnp.float32)
    v = v_ref[0, 0, 0].astype(jnp.float32)
    gi = gi_ref[0, 0, 0].astype(jnp.float32)           # (L,)
    gf = gf_ref[0, 0, 0].astype(jnp.float32)
    C = c_ref[0, 0].astype(jnp.float32)                # (dh, dh)
    n = n_ref[0, 0].astype(jnp.float32)                # (1, dh)
    m_prev = m_ref[0, 0][0]                            # scalar

    b = jnp.cumsum(gf)                                 # (L,)
    gmb = _cummax(gi - b)
    m_new = b + jnp.maximum(m_prev, gmb)               # (L,)
    inter = jnp.exp(b + m_prev - m_new)                # (L,)
    dmat = (b[:, None] - b[None, :] + gi[None, :] - m_new[:, None])
    ii = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    gate = jnp.where(jj <= ii, jnp.exp(dmat), 0.0)
    sc = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))   # (L, L)
    att = gate * sc
    num = (jax.lax.dot_general(att, v, (((1,), (0,)), ((), ())))
           + inter[:, None] * jax.lax.dot_general(
               q, C, (((1,), (0,)), ((), ()))))
    qn = (q * n).sum(axis=1)                           # (L,)
    den = att.sum(axis=1) + inter * qn
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[:, None]
    # state update
    w_end = gate[L - 1]                                # (L,)
    C_new = inter[L - 1] * C + jax.lax.dot_general(
        k * w_end[:, None], v, (((0,), (0,)), ((), ())))
    n_new = inter[L - 1] * n + (k * w_end[:, None]).sum(axis=0)[None]
    h_ref[0, 0, 0] = h.astype(h_ref.dtype)
    c_ref[0, 0] = C_new
    n_ref[0, 0] = n_new
    m_ref[0, 0] = m_new[L - 1][None]


def mlstm_pallas(q, k, v, log_i, log_f, state0=None, *, scale=None,
                 interpret=False):
    """q/k/v: (B, H, C, L, dh); log_i/log_f: (B, H, C, L).

    Returns (h (B,H,C,L,dh), (C (B,H,dh,dh), n (B,H,dh), m (B,H)))."""
    B, H, C, L, dh = q.shape
    scale = scale if scale is not None else 1.0
    if state0 is None:
        c0 = jnp.zeros((B, H, dh, dh), jnp.float32)
        n0 = jnp.zeros((B, H, 1, dh), jnp.float32)
        m0 = jnp.full((B, H, 1), NEG, jnp.float32)
    else:
        c0, n0, m0 = state0
        n0 = n0.reshape(B, H, 1, dh)
        m0 = m0.reshape(B, H, 1)
    kern = functools.partial(_mlstm_kernel, L=L, scale=scale)
    grid = (B * H, C)
    spec5 = pl.BlockSpec((1, 1, 1, L, dh),
                         lambda bh, c: (bh // H, bh % H, c, 0, 0))
    spec4 = pl.BlockSpec((1, 1, 1, L),
                         lambda bh, c: (bh // H, bh % H, c, 0))
    spec_c = pl.BlockSpec((1, 1, dh, dh),
                          lambda bh, c: (bh // H, bh % H, 0, 0))
    spec_n = pl.BlockSpec((1, 1, 1, dh),
                          lambda bh, c: (bh // H, bh % H, 0, 0))
    spec_m = pl.BlockSpec((1, 1, 1), lambda bh, c: (bh // H, bh % H, 0))
    h, c_f, n_f, m_f = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[spec5, spec5, spec5, spec4, spec4,
                  spec_c, spec_n, spec_m],
        out_specs=[spec5, spec_c, spec_n, spec_m],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, C, L, dh), q.dtype),
            jax.ShapeDtypeStruct((B, H, dh, dh), jnp.float32),
            jax.ShapeDtypeStruct((B, H, 1, dh), jnp.float32),
            jax.ShapeDtypeStruct((B, H, 1), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(q, k, v, log_i, log_f, c0, n0, m0)
    return h, (c_f, n_f.reshape(B, H, dh), m_f.reshape(B, H))
