"""jit'd wrapper for the chunkwise mLSTM kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.mlstm.kernel import mlstm_pallas
from repro.kernels.mlstm.ref import mlstm_ref


@functools.partial(jax.jit, static_argnames=("scale", "impl"))
def mlstm_chunkwise(q, k, v, log_i, log_f, state0=None, *, scale=None,
                    impl="auto"):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return mlstm_ref(q, k, v, log_i, log_f, state0, scale=scale)
    return mlstm_pallas(q, k, v, log_i, log_f, state0, scale=scale,
                        interpret=impl == "interpret")
