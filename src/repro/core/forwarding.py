"""Overlay forwarding decision (Algorithm 2) + relative-load balancing
+ prefix-affinity routing over block-digest sketches.

Executed by EVERY model node on receiving a user request: check the
peers' prefix sketches for the longest cached block-aligned prefix and
route to its holder unless that holder is under memory or load pressure;
otherwise search the HR-tree; on a match, filter holders above the load
threshold and pick the least (relatively) loaded; on a miss (or all
holders overloaded), fall back to global least-relative-load.  Relative
load = active requests / hardware score (1..10), per §3.3.

The sketch is a fixed-size bloom fingerprint over the chain digests that
``serving/prefix_cache.py`` registers per BLOCK of every cached stream.
It is finer-grained than the HR-tree (BLOCK=32 tokens vs the 64-token
sync chunks) and per-peer rather than aggregated, so a sibling request
whose prefix is cached on exactly one node routes there instead of
re-prefilling the same KV bytes on a load-picked stranger.  False
positives only cost a wasted co-location (the target re-prefills); they
never affect correctness, and the prefix-scan containment test keeps the
effective rate at fp^depth.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

SKETCH_BYTES = 64              # bloom filter size (512 bits)
SKETCH_HASHES = 4              # buckets per digest


def _sketch_buckets(digest: bytes, m_bits: int = SKETCH_BYTES * 8,
                    k: int = SKETCH_HASHES) -> list[int]:
    """Bucket indices for one chain digest.  Digests are SHA-256 prefixes
    (serving/prefix_cache._chain_hashes) — already uniform, so slicing
    2-byte windows gives k independent buckets without re-hashing."""
    return [int.from_bytes(digest[2 * i:2 * i + 2], "little") % m_bits
            for i in range(k)]


class PrefixSketch:
    """Fixed-size bloom fingerprint over block-chain digests.

    Built by a model node over its prefix cache's registered chain keys
    (one per BLOCK depth of every cached stream) and broadcast in every
    HR-tree sync; ``decide`` probes it with the request's own chain
    digests to find the peer holding the longest cached prefix."""

    __slots__ = ("bits",)

    def __init__(self, bits: int = 0):
        self.bits = bits

    @classmethod
    def build(cls, digests) -> "PrefixSketch":
        s = cls()
        for d in digests:
            s.add(d)
        return s

    @classmethod
    def from_bytes(cls, data: bytes) -> "PrefixSketch":
        return cls(int.from_bytes(data, "little"))

    def to_bytes(self) -> bytes:
        return self.bits.to_bytes(SKETCH_BYTES, "little")

    def add(self, digest: bytes):
        for b in _sketch_buckets(digest):
            self.bits |= 1 << b

    def __contains__(self, digest: bytes) -> bool:
        return all(self.bits >> b & 1 for b in _sketch_buckets(digest))

    def hit_depth(self, digests: Sequence[bytes]) -> int:
        """Longest prefix of ``digests`` fully contained in the sketch.

        Chain digests are cumulative, so a true cache entry registers
        every shallower depth too — scanning forward and stopping at the
        first miss compounds the bloom false-positive rate per block."""
        d = 0
        for dg in digests:
            if dg not in self:
                break
            d += 1
        return d


@dataclass
class PeerInfo:
    node_id: object
    hw_score: float = 5.0          # 1..10 hardware capacity score
    active_requests: int = 0
    latency_ms: float = 0.0
    kv_usage: float = 0.0
    # fraction of the peer's paged-KV arena in use (0..1); 0 when the peer
    # has no paged real engine.  Broadcast by model nodes so forwarding can
    # see memory pressure, not just slot occupancy.
    kv_pressure: float = 0.0
    # fraction of speculative draft tokens the peer's engine accepted
    # (0..1; 0 until it drafts).  Broadcast alongside kv_pressure — an
    # accept-rate-aware router can prefer peers whose verify rounds commit
    # multiple tokens per dispatch (reported only for now; see ROADMAP).
    spec_accept_rate: float = 0.0
    # serialized PrefixSketch (SKETCH_BYTES bloom over the peer's cached
    # block-chain digests), refreshed by every hr_sync; None until the
    # peer's first broadcast — affinity then simply skips it.
    prefix_sketch: Optional[bytes] = None
    _sketch_memo: object = None    # (bytes, PrefixSketch) decode cache

    @property
    def relative_load(self) -> float:
        return self.active_requests / max(self.hw_score, 1e-6)

    def sketch(self) -> Optional[PrefixSketch]:
        """Deserialized prefix sketch, memoized per broadcast payload —
        decide() probes every peer on every request, but the sketch only
        changes when an hr_sync replaces ``prefix_sketch``."""
        raw = self.prefix_sketch
        if not raw:
            return None
        if self._sketch_memo is None or self._sketch_memo[0] is not raw:
            self._sketch_memo = (raw, PrefixSketch.from_bytes(raw))
        return self._sketch_memo[1]


@dataclass
class ForwardingConfig:
    tau_match: int = 2             # min HR-tree depth for a cache match
    load_threshold: float = 4.0    # max relative load for cache-affinity pick
    bits: int = 8
    affinity: bool = True          # sketch-based prefix-affinity routing
    affinity_min_blocks: int = 1   # min BLOCK-chain depth for an affinity pick
    kv_pressure_max: float = 0.85  # veto affinity into a nearly-full arena
    # affinity gets a TIGHTER load bound than the HR-tree holder pick:
    # concentrating siblings is only a win while the holder has slack —
    # past ~1 active request per hw point, queueing outweighs the saved
    # prefill and the balancer must take over
    affinity_load_max: float = 1.0


@dataclass
class Decision:
    target: object
    reason: str            # "affinity" | "cache_hit" | "load_balance" | "self"
    depth: int = 0
    candidates: tuple = ()


def _tiebreak(node_id, tokens) -> int:
    """Per-request pseudo-random tiebreak: equal-load nodes would otherwise
    herd onto one member between state-sync ticks."""
    import zlib
    return zlib.crc32(f"{node_id}|{list(tokens[:8])}".encode())


def _sketch_affinity(cfg: ForwardingConfig, peers: dict, tokens
                     ) -> tuple[Optional[PeerInfo], int, tuple]:
    """Deepest eligible sketch hit across peers, or (None, 0, ()).

    A peer is eligible when its sketch covers at least
    ``affinity_min_blocks`` leading blocks of the request AND it is not
    vetoed by memory pressure (``kv_pressure_max``) or relative load
    (``affinity_load_max``) — affinity must never pile siblings onto a
    node that would evict the very prefix they came for, or queue them
    behind a backlog that costs more than the prefill they skip."""
    if not any(p.prefix_sketch for p in peers.values()):
        return None, 0, ()      # cold start / latency-only overlay: don't
                                # pay the digest chain for nobody
    # local import: prefix_cache imports nothing from core, so the digest
    # function is reached lazily to keep this module stdlib-only at import
    from repro.serving.prefix_cache import _chain_hashes
    digests = _chain_hashes(tokens)
    if not digests:
        return None, 0, ()
    hits = []
    for p in peers.values():
        sk = p.sketch()
        if sk is None:
            continue
        d = sk.hit_depth(digests)
        if d < cfg.affinity_min_blocks:
            continue
        if p.kv_pressure > cfg.kv_pressure_max:
            continue
        if p.relative_load > cfg.affinity_load_max:
            continue
        hits.append((d, p))
    if not hits:
        return None, 0, ()
    best_d = max(d for d, _ in hits)
    cands = [p for d, p in hits if d == best_d]
    best = min(cands, key=lambda p: (p.relative_load, p.latency_ms,
                                     _tiebreak(p.node_id, tokens)))
    return best, best_d, tuple(p.node_id for p in cands)


def decide(cfg: ForwardingConfig, hrtree, peers: dict, tokens,
           self_id=None) -> Decision:
    """peers: {node_id: PeerInfo} for the whole group (state sync view)."""
    live = {nid: p for nid, p in peers.items()}
    if cfg.affinity:
        best, d_aff, cands = _sketch_affinity(cfg, live, tokens)
        if best is not None:
            return Decision(best.node_id, "affinity", d_aff, cands)
    holders, depth = hrtree.search_tokens(tokens, cfg.tau_match)
    if holders:
        cands = [live[h] for h in holders if h in live]
        cands = [p for p in cands if p.relative_load <= cfg.load_threshold]
        if cands:
            best = min(cands, key=lambda p: (p.relative_load, p.latency_ms,
                                             _tiebreak(p.node_id, tokens)))
            return Decision(best.node_id, "cache_hit", depth,
                            tuple(p.node_id for p in cands))
    if not live:
        return Decision(self_id, "self", depth)
    best = min(live.values(), key=lambda p: (p.relative_load, p.latency_ms,
                                             _tiebreak(p.node_id, tokens)))
    return Decision(best.node_id, "load_balance", depth)
