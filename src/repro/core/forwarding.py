"""Overlay forwarding decision (Algorithm 2) + relative-load balancing.

Executed by EVERY model node on receiving a user request: search the
HR-tree; on a match, filter holders above the load threshold and pick the
least (relatively) loaded; on a miss (or all holders overloaded), fall back
to global least-relative-load.  Relative load = active requests / hardware
score (1..10), per §3.3.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence


@dataclass
class PeerInfo:
    node_id: object
    hw_score: float = 5.0          # 1..10 hardware capacity score
    active_requests: int = 0
    latency_ms: float = 0.0
    kv_usage: float = 0.0
    # fraction of the peer's paged-KV arena in use (0..1); 0 when the peer
    # has no paged real engine.  Broadcast by model nodes so forwarding can
    # see memory pressure, not just slot occupancy.
    kv_pressure: float = 0.0

    @property
    def relative_load(self) -> float:
        return self.active_requests / max(self.hw_score, 1e-6)


@dataclass
class ForwardingConfig:
    tau_match: int = 2             # min HR-tree depth for a cache match
    load_threshold: float = 4.0    # max relative load for cache-affinity pick
    bits: int = 8


@dataclass
class Decision:
    target: object
    reason: str                    # "cache_hit" | "load_balance" | "self"
    depth: int = 0
    candidates: tuple = ()


def _tiebreak(node_id, tokens) -> int:
    """Per-request pseudo-random tiebreak: equal-load nodes would otherwise
    herd onto one member between state-sync ticks."""
    import zlib
    return zlib.crc32(f"{node_id}|{list(tokens[:8])}".encode())


def decide(cfg: ForwardingConfig, hrtree, peers: dict, tokens,
           self_id=None) -> Decision:
    """peers: {node_id: PeerInfo} for the whole group (state sync view)."""
    holders, depth = hrtree.search_tokens(tokens, cfg.tau_match)
    live = {nid: p for nid, p in peers.items()}
    if holders:
        cands = [live[h] for h in holders if h in live]
        cands = [p for p in cands if p.relative_load <= cfg.load_threshold]
        if cands:
            best = min(cands, key=lambda p: (p.relative_load, p.latency_ms,
                                             _tiebreak(p.node_id, tokens)))
            return Decision(best.node_id, "cache_hit", depth,
                            tuple(p.node_id for p in cands))
    if not live:
        return Decision(self_id, "self", depth)
    best = min(live.values(), key=lambda p: (p.relative_load, p.latency_ms,
                                             _tiebreak(p.node_id, tokens)))
    return Decision(best.node_id, "load_balance", depth)
