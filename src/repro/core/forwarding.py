"""Overlay forwarding decision (Algorithm 2) + relative-load balancing
+ prefix-affinity routing over block-digest sketches.

Executed by EVERY model node on receiving a user request: check the
peers' prefix sketches for the longest cached block-aligned prefix and
route to its holder unless that holder is under memory or load pressure;
otherwise search the HR-tree; on a match, filter holders above the load
threshold and pick the least (relatively) loaded; on a miss (or all
holders overloaded), fall back to global least-relative-load.  Relative
load = active requests / hardware score (1..10), per §3.3.

The sketch is a fixed-size bloom fingerprint over the chain digests that
``serving/prefix_cache.py`` registers per BLOCK of every cached stream.
It is finer-grained than the HR-tree (BLOCK=32 tokens vs the 64-token
sync chunks) and per-peer rather than aggregated, so a sibling request
whose prefix is cached on exactly one node routes there instead of
re-prefilling the same KV bytes on a load-picked stranger.  False
positives only cost a wasted co-location (the target re-prefills); they
never affect correctness, and the prefix-scan containment test keeps the
effective rate at fp^depth.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

SKETCH_BYTES = 64              # smallest bloom size on the ladder (512 bits)
SKETCH_HASHES = 4              # buckets per digest
# power-of-two ladder: a cache whose live chain-key count outgrows one
# rung rebuilds its sketch at the next (the hr_sync wire field carries
# raw bytes, so any rung deserializes); capped so a pathological cache
# cannot inflate every sync broadcast unboundedly
SKETCH_LADDER = (64, 128, 256, 512, 1024)
SKETCH_BITS_PER_KEY = 16       # >= 16 bits/key keeps fp ~ 0.2% at k=4


def sketch_size_for(n_keys: int) -> int:
    """Smallest ladder size (bytes) holding ``n_keys`` digests at the
    bounded-fp bit budget; the top rung once the budget can't be met."""
    for nbytes in SKETCH_LADDER:
        if n_keys * SKETCH_BITS_PER_KEY <= nbytes * 8:
            return nbytes
    return SKETCH_LADDER[-1]


def _sketch_buckets(digest: bytes, m_bits: int,
                    k: int = SKETCH_HASHES) -> list[int]:
    """Bucket indices for one chain digest.  Digests are SHA-256 prefixes
    (serving/prefix_cache._chain_hashes) — already uniform, so slicing
    2-byte windows gives k independent buckets without re-hashing."""
    return [int.from_bytes(digest[2 * i:2 * i + 2], "little") % m_bits
            for i in range(k)]


class PrefixSketch:
    """Bloom fingerprint over block-chain digests, sized off the ladder.

    Built by a model node over its prefix cache's registered chain keys
    (one per BLOCK depth of every cached stream) and broadcast in every
    HR-tree sync; ``decide`` probes it with the request's own chain
    digests to find the peer holding the longest cached prefix.
    ``from_bytes`` accepts any ladder size — the wire field is raw bytes,
    so peers on different rungs interoperate."""

    __slots__ = ("bits", "nbytes")

    def __init__(self, bits: int = 0, nbytes: int = SKETCH_BYTES):
        self.bits = bits
        self.nbytes = nbytes

    @classmethod
    def build(cls, digests, nbytes: Optional[int] = None) -> "PrefixSketch":
        digests = list(digests)
        s = cls(nbytes=nbytes or sketch_size_for(len(digests)))
        for d in digests:
            s.add(d)
        return s

    @classmethod
    def from_bytes(cls, data: bytes) -> "PrefixSketch":
        return cls(int.from_bytes(data, "little"), max(len(data), 1))

    def to_bytes(self) -> bytes:
        return self.bits.to_bytes(self.nbytes, "little")

    def add(self, digest: bytes):
        for b in _sketch_buckets(digest, self.nbytes * 8):
            self.bits |= 1 << b

    def __contains__(self, digest: bytes) -> bool:
        return all(self.bits >> b & 1
                   for b in _sketch_buckets(digest, self.nbytes * 8))

    def hit_depth(self, digests: Sequence[bytes]) -> int:
        """Longest prefix of ``digests`` fully contained in the sketch.

        Chain digests are cumulative, so a true cache entry registers
        every shallower depth too — scanning forward and stopping at the
        first miss compounds the bloom false-positive rate per block."""
        d = 0
        for dg in digests:
            if dg not in self:
                break
            d += 1
        return d


@dataclass
class PeerInfo:
    node_id: object
    hw_score: float = 5.0          # 1..10 hardware capacity score
    active_requests: int = 0
    latency_ms: float = 0.0
    kv_usage: float = 0.0
    # fraction of the peer's paged-KV arena in use (0..1); 0 when the peer
    # has no paged real engine.  Broadcast by model nodes so forwarding can
    # see memory pressure, not just slot occupancy.
    kv_pressure: float = 0.0
    # fraction of speculative draft tokens the peer's engine accepted
    # (0..1; 0 until it drafts).  Broadcast alongside kv_pressure and
    # consumed by decide(): decode-heavy requests break load ties toward
    # the peer committing the most tokens per verify dispatch
    # (ForwardingConfig.accept_rate_routing).
    spec_accept_rate: float = 0.0
    # serialized PrefixSketch (SKETCH_BYTES bloom over the peer's cached
    # block-chain digests), refreshed by every hr_sync; None until the
    # peer's first broadcast — affinity then simply skips it.
    prefix_sketch: Optional[bytes] = None
    _sketch_memo: object = None    # (bytes, PrefixSketch) decode cache

    @property
    def relative_load(self) -> float:
        return self.active_requests / max(self.hw_score, 1e-6)

    def sketch(self) -> Optional[PrefixSketch]:
        """Deserialized prefix sketch, memoized per broadcast payload —
        decide() probes every peer on every request, but the sketch only
        changes when an hr_sync replaces ``prefix_sketch``."""
        raw = self.prefix_sketch
        if not raw:
            return None
        if self._sketch_memo is None or self._sketch_memo[0] is not raw:
            self._sketch_memo = (raw, PrefixSketch.from_bytes(raw))
        return self._sketch_memo[1]


@dataclass
class ForwardingConfig:
    tau_match: int = 2             # min HR-tree depth for a cache match
    load_threshold: float = 4.0    # max relative load for cache-affinity pick
    bits: int = 8
    affinity: bool = True          # sketch-based prefix-affinity routing
    affinity_min_blocks: int = 1   # min BLOCK-chain depth for an affinity pick
    kv_pressure_max: float = 0.85  # veto affinity into a nearly-full arena
    # affinity gets a TIGHTER load bound than the HR-tree holder pick:
    # concentrating siblings is only a win while the holder has slack —
    # past ~1 active request per hw point, queueing outweighs the saved
    # prefill and the balancer must take over
    affinity_load_max: float = 1.0
    # cross-node KV page replication: when every sketch hit is vetoed by
    # pressure/load, route to the least-loaded eligible peer WITH a fetch
    # hint (vetoed holder id + hit depth) so the target pulls the prefix
    # pages over the overlay instead of re-prefilling them — the
    # kv_pressure signal used in reverse: the holder sheds traffic
    # without losing the prefix.  Short prefixes re-prefill cheaper than
    # they ship; ``replicate_min_blocks`` is that floor.
    replicate: bool = True
    replicate_min_blocks: int = 2
    # a holder under extreme arena pressure refuses kv_fetch (the entry
    # is about to be evicted anyway; the importer just prefills)
    export_pressure_max: float = 0.98
    # accept-rate-aware routing: decode-heavy requests (n_out exceeds the
    # prompt length) break load ties toward peers whose speculative
    # verify rounds commit more tokens per dispatch — the decode-side
    # analogue of prefix affinity's prefill-side preference
    accept_rate_routing: bool = True


@dataclass
class Decision:
    target: object
    # "affinity" | "replicate" | "cache_hit" | "load_balance" | "self"
    reason: str
    depth: int = 0
    candidates: tuple = ()
    # replicate only: the vetoed sketch holder the target should pull
    # ``depth`` blocks of prefix pages from before admitting the request
    fetch_from: object = None


def _tiebreak(node_id, tokens) -> int:
    """Per-request pseudo-random tiebreak: equal-load nodes would otherwise
    herd onto one member between state-sync ticks."""
    import zlib
    return zlib.crc32(f"{node_id}|{list(tokens[:8])}".encode())


def _sketch_hits(cfg: ForwardingConfig, peers: dict, tokens) -> list:
    """(depth, peer) for every sketch covering at least
    ``affinity_min_blocks`` leading blocks of the request — veto-free;
    the caller partitions into routable hits and pressure/load-vetoed
    holders (which the replicate path can still pull pages from)."""
    if not any(p.prefix_sketch for p in peers.values()):
        return []               # cold start / latency-only overlay: don't
                                # pay the digest chain for nobody
    # local import: prefix_cache imports nothing from core, so the digest
    # function is reached lazily to keep this module stdlib-only at import
    from repro.serving.prefix_cache import _chain_hashes
    digests = _chain_hashes(tokens)
    if not digests:
        return []
    hits = []
    for p in peers.values():
        sk = p.sketch()
        if sk is None:
            continue
        d = sk.hit_depth(digests)
        if d >= cfg.affinity_min_blocks:
            hits.append((d, p))
    return hits


def _affinity_vetoed(cfg: ForwardingConfig, p: PeerInfo) -> bool:
    """Affinity must never pile siblings onto a node that would evict the
    very prefix they came for (``kv_pressure_max``), or queue them behind
    a backlog that costs more than the prefill they skip
    (``affinity_load_max``)."""
    return (p.kv_pressure > cfg.kv_pressure_max
            or p.relative_load > cfg.affinity_load_max)


def decide(cfg: ForwardingConfig, hrtree, peers: dict, tokens,
           self_id=None, n_out: int = 0) -> Decision:
    """peers: {node_id: PeerInfo} for the whole group (state sync view).

    ``n_out`` (expected generation length) makes the load-balance
    tiebreak accept-rate-aware: a decode-heavy request, whose cost is
    verify dispatches rather than prefill, breaks load ties toward the
    peer committing the most draft tokens per dispatch."""
    live = {nid: p for nid, p in peers.items()}
    decode_heavy = bool(cfg.accept_rate_routing and n_out > len(tokens))

    def rank(p: PeerInfo):
        # accept rate sorts strictly AFTER load — it breaks ties, never
        # outvotes the balancer — and before latency/tiebreak so equal-
        # rate peers keep the exact legacy (deterministic) ordering
        spec = -p.spec_accept_rate if decode_heavy else 0.0
        return (p.relative_load, spec, p.latency_ms,
                _tiebreak(p.node_id, tokens))

    if cfg.affinity:
        hits = _sketch_hits(cfg, live, tokens)
        routable = [(d, p) for d, p in hits
                    if not _affinity_vetoed(cfg, p)]
        if routable:
            best_d = max(d for d, _ in routable)
            cands = [p for d, p in routable if d == best_d]
            best = min(cands, key=rank)
            return Decision(best.node_id, "affinity", best_d,
                            tuple(p.node_id for p in cands))
        if cfg.replicate and hits:
            # every sketch hit is vetoed: instead of silently dropping
            # the affinity and re-prefilling the hottest prefix on a
            # load-picked stranger, route to the least-loaded peer that
            # can HOST the pages and tell it where to pull them from
            best_d = max(d for d, _ in hits)
            if best_d >= cfg.replicate_min_blocks:
                holder = min((p for d, p in hits if d == best_d), key=rank)
                targets = [p for p in live.values()
                           if p.node_id != holder.node_id
                           and not _affinity_vetoed(cfg, p)]
                if targets:
                    best = min(targets, key=rank)
                    return Decision(best.node_id, "replicate", best_d,
                                    (holder.node_id,),
                                    fetch_from=holder.node_id)
    holders, depth = hrtree.search_tokens(tokens, cfg.tau_match)
    if holders:
        cands = [live[h] for h in holders if h in live]
        cands = [p for p in cands if p.relative_load <= cfg.load_threshold]
        if cands:
            best = min(cands, key=rank)
            return Decision(best.node_id, "cache_hit", depth,
                            tuple(p.node_id for p in cands))
    if not live:
        return Decision(self_id, "self", depth)
    best = min(live.values(), key=rank)
    return Decision(best.node_id, "load_balance", depth)
