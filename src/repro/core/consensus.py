"""Tendermint-style verification epochs (§3.4).

Committee of N = 3f+1 verification nodes.  Per epoch e_i:
  - leader L_i chosen verifiably from the previous epoch's commit hash (VRF)
  - the (model-node, challenge-prompt) list M_i was agreed at the END of
    e_{i-1} (prevents a malicious leader from skipping/SWAPPING prompts)
  - L_i sends challenges through the anonymous overlay (model nodes cannot
    distinguish them from user traffic), collects signed responses,
    broadcasts them
  - every member independently recomputes credibility with its LOCAL model,
    compares to the leader's proposal (negligible-variance check), then
    two-phase votes (pre-vote / pre-commit, each needing > 2/3)
  - mismatched prompts / bad signatures abort the epoch (new leader next)
  - "invalid response from x" only damages x if > 1/3 of members confirm

The machinery is deterministic and in-process (the paper uses Tendermint as
a black box); Byzantine member behaviors are injectable for tests.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.core import vrf
from repro.core.reputation import ReputationConfig, ReputationTracker


@dataclass
class Challenge:
    model_node: object
    prompt: tuple           # token ids


@dataclass
class SignedResponse:
    model_node: object
    prompt: tuple
    response: tuple         # token ids
    signature: bytes        # model node's signature over (prompt, response)
    valid_sig: bool = True


@dataclass
class EpochResult:
    epoch: int
    leader: int
    committed: bool
    scores: dict = field(default_factory=dict)       # node -> C(T)
    reputations: dict = field(default_factory=dict)  # node -> R(T)
    aborted_reason: str = ""


def score_close(a: float, b: float, tol: float = 5e-2) -> bool:
    """"Negligible variance" acceptance between members' local scores."""
    return abs(a - b) <= tol


class VerificationCommittee:
    """n member slots; member i scores via score_fns[i] (its local LLM)."""

    def __init__(self, n_members: int, score_fns: list,
                 rep_cfg: ReputationConfig = ReputationConfig(),
                 byzantine: Optional[set] = None, vote_tol: float = 5e-2):
        assert n_members >= 4, "BFT needs n >= 3f+1 >= 4"
        assert len(score_fns) == n_members
        self.n = n_members
        self.f = (n_members - 1) // 3
        self.score_fns = score_fns
        self.reputation = ReputationTracker(rep_cfg)
        self.byzantine = byzantine or set()
        self.vote_tol = vote_tol
        self.commit_hash = b"genesis"
        self.epoch = 0
        self.pending: list[Challenge] = []   # agreed M_i for this epoch
        self.log: list[EpochResult] = []

    # ---- leader election (VRF over previous commit hash) ----
    def leader(self) -> int:
        return vrf.leader_index([self.commit_hash], self.n)

    def agree_challenges(self, challenges: list[Challenge]):
        """End-of-previous-epoch agreement on M_i (no duplicate prompts
        across model nodes — anti-collusion/replay, §3.4)."""
        prompts = [c.prompt for c in challenges]
        assert len(set(prompts)) == len(prompts), \
            "challenge prompts must be unique per model node"
        self.pending = list(challenges)

    # ---- one epoch ----
    def run_epoch(self, collect_fn: Callable[[int, list], list]
                  ) -> EpochResult:
        """collect_fn(leader_ix, challenges) -> list[SignedResponse]
        (the leader querying model nodes through the anonymous overlay)."""
        self.epoch += 1
        ldr = self.leader()
        challenges = self.pending
        res = EpochResult(self.epoch, ldr, committed=False)
        responses = collect_fn(ldr, challenges)

        # integrity check by every member: prompts match the agreed list,
        # signatures verify
        agreed = {c.model_node: c.prompt for c in challenges}
        for r in responses:
            if r.model_node not in agreed or agreed[r.model_node] != r.prompt:
                res.aborted_reason = f"prompt mismatch for {r.model_node}"
                self._abort()
                self.log.append(res)
                return res
            if not r.valid_sig:
                res.aborted_reason = f"bad signature from {r.model_node}"
                self._abort()
                self.log.append(res)
                return res

        # leader proposal: per-node scores (leader may be byzantine)
        by_node: dict = {}
        for r in responses:
            by_node.setdefault(r.model_node, []).append(r)
        proposal = {}
        for node, rs in by_node.items():
            pairs = [(list(r.prompt), list(r.response)) for r in rs]
            c = self.score_fns[ldr](pairs)
            if ldr in self.byzantine:
                c = 1.0 - c  # byzantine leader proposes garbage
            proposal[node] = c

        # pre-vote: each member recomputes locally and compares
        prevotes = 0
        for m in range(self.n):
            if m in self.byzantine:
                continue  # byzantine members withhold votes
            ok = True
            for node, rs in by_node.items():
                pairs = [(list(r.prompt), list(r.response)) for r in rs]
                mine = self.score_fns[m](pairs)
                if not score_close(mine, proposal[node], self.vote_tol):
                    ok = False
                    break
            prevotes += 1 if ok else 0
        if prevotes * 3 <= 2 * self.n:
            res.aborted_reason = (f"pre-vote failed ({prevotes}/{self.n})")
            self._abort()
            self.log.append(res)
            return res

        # pre-commit mirrors pre-vote for honest members
        precommits = self.n - len(self.byzantine)
        if precommits * 3 <= 2 * self.n:
            res.aborted_reason = "pre-commit failed"
            self._abort()
            self.log.append(res)
            return res

        # commit: apply reputation updates
        for node, c in proposal.items():
            res.scores[node] = c
            res.reputations[node] = self.reputation.update(node, c)
        res.committed = True
        self.commit_hash = hashlib.sha256(
            self.commit_hash
            + json.dumps({str(k): round(v, 6)
                          for k, v in sorted(res.scores.items(),
                                             key=lambda kv: str(kv[0]))
                          }).encode()).digest()
        self.log.append(res)
        return res

    def _abort(self):
        # rotate leadership: fold the failed epoch into the hash chain
        self.commit_hash = hashlib.sha256(
            self.commit_hash + b"abort" + bytes([self.epoch % 256])).digest()

    def untrusted(self) -> set:
        cfg = self.reputation.cfg
        return {n for n, st in self.reputation.nodes.items()
                if st.score < cfg.untrusted_below}
