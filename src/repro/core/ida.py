"""Rabin's Information Dispersal Algorithm: systematic k-of-n erasure code
over GF(256) with a Cauchy extension matrix.

Each fragment is ~|M|/k bytes (Rabin's space optimality).  The first k rows
are the identity (fragments 0..k-1 are plain data slices — S-IDA encrypts
the payload first, so this leaks nothing), rows k..n-1 are Cauchy rows
1/(x_i ^ y_j), any k of which are invertible with the identity rows.
"""
from __future__ import annotations

import struct

import numpy as np

from repro.core import gf256


def _matrix(n: int, k: int) -> np.ndarray:
    assert k <= n <= 128
    M = np.zeros((n, k), np.uint8)
    M[:k] = np.eye(k, dtype=np.uint8)
    xs = np.arange(k, n, dtype=np.uint8)          # x_i for parity rows
    ys = np.arange(128, 128 + k, dtype=np.uint8)  # y_j disjoint from xs
    denom = xs[:, None] ^ ys[None, :]
    M[k:] = gf256.inv(denom)
    return M


def split(data: bytes, n: int, k: int) -> list[tuple[int, bytes]]:
    """Fragments [(index, piece)]; original length is prepended."""
    blob = struct.pack("<I", len(data)) + data
    pad = (-len(blob)) % k
    blob += b"\0" * pad
    cols = np.frombuffer(blob, np.uint8).reshape(k, len(blob) // k)
    M = _matrix(n, k)
    frags = gf256.matmul(M, cols)                 # (n, L/k)
    return [(i, frags[i].tobytes()) for i in range(n)]


def combine(frags: list[tuple[int, bytes]], n: int, k: int) -> bytes:
    assert len(frags) >= k, "need at least k fragments"
    frags = frags[:k]
    idx = [f[0] for f in frags]
    Y = np.stack([np.frombuffer(f[1], np.uint8) for f in frags])
    M = _matrix(n, k)[idx]                        # (k, k)
    cols = gf256.matmul(gf256.mat_inv(M), Y)      # (k, L/k)
    blob = cols.reshape(-1).tobytes()
    (length,) = struct.unpack("<I", blob[:4])
    return blob[4:4 + length]
