"""Shamir k-of-n secret sharing over GF(256), byte-vectorized.

Each byte of the secret gets an independent degree-(k-1) polynomial; share i
is the evaluation at x_i = i (1-based).  < k shares reveal nothing
(information-theoretic); used by S-IDA for the symmetric key.
"""
from __future__ import annotations

import os

import numpy as np

from repro.core import gf256


def split(secret: bytes, n: int, k: int, rng=None) -> list[tuple[int, bytes]]:
    assert 1 <= k <= n <= 255
    L = len(secret)
    rnd = (np.frombuffer(os.urandom(L * (k - 1)), np.uint8)
           .reshape(k - 1, L) if rng is None else
           rng.integers(0, 256, (k - 1, L), dtype=np.uint8))
    coeffs = np.concatenate([np.frombuffer(secret, np.uint8)[None],
                             rnd.reshape(k - 1, L)], axis=0)  # (k, L)
    shares = []
    for i in range(1, n + 1):
        x = np.uint8(i)
        acc = np.zeros(L, np.uint8)
        for j in range(k - 1, -1, -1):  # Horner
            acc = gf256.mul(acc, x) ^ coeffs[j]
        shares.append((i, acc.tobytes()))
    return shares


def combine(shares: list[tuple[int, bytes]], k: int) -> bytes:
    assert len(shares) >= k
    shares = shares[:k]
    xs = np.array([s[0] for s in shares], np.uint8)
    ys = np.stack([np.frombuffer(s[1], np.uint8) for s in shares])  # (k, L)
    # Lagrange interpolation at 0: secret = sum_i y_i * prod_{j!=i} x_j/(x_i^x_j)
    L = ys.shape[1]
    out = np.zeros(L, np.uint8)
    for i in range(k):
        num = np.uint8(1)
        den = np.uint8(1)
        for j in range(k):
            if i == j:
                continue
            num = gf256.mul(num, xs[j])
            den = gf256.mul(den, xs[i] ^ xs[j])
        lam = gf256.mul(num, gf256.inv(den))
        out ^= gf256.mul(ys[i], lam)
    return out.tobytes()
