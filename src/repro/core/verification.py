"""Token-level probabilistic verification (Algorithm 3, Fig 8).

A verification node holds its own copy of the served LLM (a JAX model from
repro.models).  Given a challenge prompt and a model node's response, it
teacher-forces the concatenated sequence through its local model ONCE and
reads the probability its reference model assigns to every response token —
the per-token loop in Algorithm 3 collapses into a single forward pass
(identical math, one HLO launch instead of n).

credibility C = 1 / PPL,  PPL = exp(-(1/n) * sum_i log p(t_i | t_<i)).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class VerifierModel:
    cfg: object
    model: object
    params: object

    def __post_init__(self):
        def logprobs(params, tokens):
            logits = self.model.apply(params, tokens)
            return jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        self._logprobs = jax.jit(logprobs)


EPS_PROB = 1e-8  # Algorithm 3's small constant for unmatched tokens


def response_logprobs(verifier: VerifierModel, prompt: list[int],
                      response: list[int]) -> np.ndarray:
    """log p(response_i | prompt, response_<i) under the local model."""
    seq = jnp.asarray([list(prompt) + list(response)], jnp.int32)
    lp = verifier._logprobs(verifier.params, seq)[0]       # (S, V)
    n0 = len(prompt)
    idx = np.arange(n0 - 1, n0 - 1 + len(response))
    toks = np.asarray(response)
    out = np.asarray(lp)[idx, toks]
    return np.maximum(out, np.log(EPS_PROB))


def credibility(verifier: VerifierModel, prompt: list[int],
                response: list[int]) -> float:
    """Normalized perplexity 1/PPL in (0, 1]."""
    if not response:
        return 0.0
    lp = response_logprobs(verifier, prompt, response)
    ppl = float(np.exp(-lp.mean()))
    return 1.0 / ppl


def avg_credibility(verifier: VerifierModel, pairs) -> float:
    """C(T): average over the epoch's (prompt, response) challenges."""
    vals = [credibility(verifier, p, r) for p, r in pairs]
    return float(np.mean(vals)) if vals else 0.0


def credibility_batch(verifier: VerifierModel, pairs) -> list[float]:
    """Batched scoring: pad challenges to one (B, S) forward pass.

    Verification-node throughput optimization (§5.4): one XLA launch for a
    whole epoch's challenges instead of per-challenge dispatches.  Exactly
    equivalent to per-pair ``credibility`` (padding rows are masked out)."""
    if not pairs:
        return []
    seqs = [list(p) + list(r) for p, r in pairs]
    S = max(len(s) for s in seqs)
    B = len(pairs)
    toks = np.zeros((B, S), np.int32)
    for i, s in enumerate(seqs):
        toks[i, :len(s)] = s
    lp = verifier._logprobs(verifier.params, jnp.asarray(toks))  # (B, S, V)
    lp = np.asarray(lp)
    out = []
    for i, (p, r) in enumerate(pairs):
        if not r:
            out.append(0.0)
            continue
        n0 = len(p)
        idx = np.arange(n0 - 1, n0 - 1 + len(r))
        vals = np.maximum(lp[i, idx, np.asarray(r)], np.log(EPS_PROB))
        out.append(float(1.0 / np.exp(-vals.mean())))
    return out
