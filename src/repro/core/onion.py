"""Onion proxy establishment + lightweight path forwarding (§3.2, Fig 2).

Establishment uses public-key crypto (X25519 + ChaCha20 layered boxes, one
ephemeral key per hop — telescoping like Tor but single-pass since the
establishment message is short and retries are cheap, per the paper).
Every relay on the path stores {path_id: (predecessor, successor)}; later
prompt/response cloves carry only the path_id in their header — NO
public-key operations on the data path (requirement 3).

Path IDs differ per path, so colluding relays on different paths of the
same user cannot link them (§3.2 security argument).
"""
from __future__ import annotations

import hashlib
import os
import struct
from dataclasses import dataclass, field

from repro.core import chacha, ed25519


@dataclass
class RelayState:
    """What one relay stores per path."""
    routes: dict = field(default_factory=dict)  # path_id -> (pred, succ)

    def install(self, path_id: bytes, pred, succ):
        self.routes[path_id] = (pred, succ)

    def next_hop(self, path_id: bytes, from_node):
        ent = self.routes.get(path_id)
        if ent is None:
            return None
        pred, succ = ent
        return succ if from_node == pred else pred

    def drop_path(self, path_id: bytes):
        self.routes.pop(path_id, None)


def _box(payload: bytes, pk: bytes) -> bytes:
    """X25519 ephemeral box: eph_pub || ChaCha20(shared, payload)."""
    esk, epub = ed25519.dh_keypair()
    shared = ed25519.dh_shared(esk, pk)
    return epub + chacha.encrypt(payload, shared)


def _unbox(blob: bytes, sk: bytes) -> bytes:
    epub, body = blob[:32], blob[32:]
    shared = ed25519.dh_shared(sk, epub)
    return chacha.decrypt(body, shared)


def make_path_id(user_pub: bytes, proxy_pub: bytes, nonce: bytes) -> bytes:
    """Paper: hash of the user and the last node on the path (+ nonce so
    multiple paths to the same proxy stay unlinkable)."""
    return hashlib.sha256(b"path:" + user_pub + proxy_pub + nonce).digest()[:16]


def build_establishment(user_id, user_pub: bytes, hops: list) -> tuple:
    """hops: [(node_id, dh_pub)] of length l (last = proxy).

    Returns (path_id, first_hop_id, onion_blob).  Layer i decrypts to
    (path_id, pred_i, succ_i, inner); the proxy's layer has succ = None and
    a PROXY-ACK marker."""
    nonce = os.urandom(8)
    path_id = make_path_id(user_pub, hops[-1][1], nonce)
    ids = [user_id] + [h[0] for h in hops]
    blob = b"PROXY" + nonce + user_pub
    for i in range(len(hops) - 1, -1, -1):
        pred = _encode_id(ids[i])
        succ = _encode_id(ids[i + 2]) if i + 2 <= len(hops) else b""
        inner = struct.pack("<16sHH", path_id, len(pred), len(succ)) + \
            pred + succ + blob
        blob = _box(inner, hops[i][1])
    return path_id, hops[0][0], blob


def peel_establishment(blob: bytes, dh_sk: bytes):
    """One relay peels its layer.  Returns (path_id, pred_id, succ_id|None,
    inner_blob|None, proxy_payload|None)."""
    inner = _unbox(blob, dh_sk)
    path_id, lp, ls = struct.unpack("<16sHH", inner[:20])
    off = 20
    pred = _decode_id(inner[off:off + lp])
    off += lp
    succ = _decode_id(inner[off:off + ls]) if ls else None
    off += ls
    rest = inner[off:]
    if succ is None and rest.startswith(b"PROXY"):
        return path_id, pred, None, None, rest[5:]
    return path_id, pred, succ, rest, None


def _encode_id(x) -> bytes:
    if isinstance(x, bytes):
        return b"B" + x
    if isinstance(x, int):
        return b"I" + struct.pack("<q", x)
    return b"S" + str(x).encode()


def _decode_id(b: bytes):
    tag, body = b[:1], b[1:]
    if tag == b"B":
        return body
    if tag == b"I":
        return struct.unpack("<q", body)[0]
    return body.decode()
