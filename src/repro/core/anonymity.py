"""Entropy-based anonymity metric + attacker models (Appendix A4, §4.1-4.2).

normalized anonymity = H(S) / log2(N) with the paper's chain-attack source
probabilities:

  Pr(x = src) = 1/(L+1-fL)                      if x in Gamma
                (1 - |Gamma|/(L+1-fL)) / ((1-f)N - |Gamma|)   otherwise

where L = #nodes on the k paths, Gamma = predecessors of maximal chains of
consecutive malicious relays.  The same simulator scores the three systems
of Fig 9 (GenTorrent, onion, garlic-cast) and the confidentiality metric of
Fig 10 (fraction of messages whose content an adversary controlling >= k
paths could decode).
"""
from __future__ import annotations

import math
import random


def entropy_from_probs(probs) -> float:
    h = 0.0
    for p in probs:
        if p > 0:
            h -= p * math.log2(p)
    return h


def chain_predecessors(paths: list[list[int]], malicious: set) -> set:
    """Gamma: predecessor of every maximal malicious chain on each path.

    paths include the source at index 0 and proxy at the end."""
    gamma = set()
    for path in paths:
        i = 1
        while i < len(path):
            if path[i] in malicious and path[i - 1] not in malicious:
                gamma.add(path[i - 1])
                while i < len(path) and path[i] in malicious:
                    i += 1
            else:
                i += 1
    return gamma


def gentorrent_anonymity(N: int, f: float, k_paths: int, path_len: int,
                         rng: random.Random) -> float:
    """One trial: build k disjoint relay paths for a random source, sample
    malicious nodes, compute normalized entropy of the source distribution."""
    malicious = set(rng.sample(range(N), int(f * N)))
    src = rng.choice([x for x in range(N) if x not in malicious])
    nodes = [x for x in range(N) if x != src]
    paths = []
    used = set()
    for _ in range(k_paths):
        avail = [x for x in nodes if x not in used]
        relays = rng.sample(avail, path_len)
        used.update(relays)
        paths.append([src] + relays)
    L = sum(len(p) - 1 for p in paths)
    gamma = chain_predecessors(paths, malicious)
    denom = L + 1 - f * L
    p_gamma = 1.0 / denom
    honest_others = (1 - f) * N - len(gamma)
    rest = max(0.0, 1.0 - len(gamma) * p_gamma)
    probs = [p_gamma] * len(gamma)
    if honest_others > 0:
        probs += [rest / honest_others] * int(honest_others)
    return entropy_from_probs(probs) / math.log2(N)


def onion_anonymity(N: int, f: float, path_len: int,
                    rng: random.Random) -> float:
    """Single onion path (per-message): entry+exit collusion deanonymizes
    (traffic confirmation); a malicious entry alone makes its predecessor
    the prime suspect; a malicious middle/exit leaks partial timing info.
    The single path concentrates all trust — the structural weakness the
    paper's Fig 9 shows against multipath designs."""
    malicious = set(rng.sample(range(N), int(f * N)))
    src = rng.choice([x for x in range(N) if x not in malicious])
    relays = rng.sample([x for x in range(N) if x != src], path_len)
    entry_bad = relays[0] in malicious
    others_bad = any(r in malicious for r in relays[1:])
    honest = int((1 - f) * N)
    if entry_bad and others_bad:
        return 0.0  # traffic confirmation
    if entry_bad:
        probs = [0.8] + [0.2 / (honest - 1)] * (honest - 1)
        return entropy_from_probs(probs) / math.log2(N)
    if others_bad:
        # timing fingerprint narrows the candidate set
        half = max(1, honest // 4)
        probs = [3 / (4 * half)] * half + \
            [1 / (4 * (honest - half))] * (honest - half)
        return entropy_from_probs(probs) / math.log2(N)
    return entropy_from_probs([1.0 / honest] * honest) / math.log2(N)


def garlic_anonymity(N: int, f: float, k_paths: int, path_len: int,
                     rng: random.Random) -> float:
    """Garlic-cast: random-walk paths share an ID per message bundle, so
    colluding relays on DIFFERENT paths of the same message can link them
    (the weakness GenTorrent's per-path IDs remove)."""
    malicious = set(rng.sample(range(N), int(f * N)))
    src = rng.choice([x for x in range(N) if x not in malicious])
    paths = []
    for _ in range(k_paths):
        relays = rng.sample([x for x in range(N) if x != src], path_len)
        paths.append([src] + relays)
    # linkable: union of observations across all paths
    gamma = chain_predecessors(paths, malicious)
    # cross-path linking: if >= 2 paths observed, intersection exposes src
    touched = sum(1 for p in paths if any(x in malicious for x in p[1:]))
    if touched >= 2 and src in gamma:
        probs = [0.75] + [0.25 / ((1 - f) * N - 1)] * int((1 - f) * N - 1)
        return entropy_from_probs(probs) / math.log2(N)
    L = sum(len(p) - 1 for p in paths)
    denom = L + 1 - f * L
    p_gamma = 1.0 / denom
    honest_others = (1 - f) * N - len(gamma)
    rest = max(0.0, 1.0 - len(gamma) * p_gamma)
    probs = [p_gamma] * len(gamma)
    if honest_others > 0:
        probs += [rest / honest_others] * int(honest_others)
    return entropy_from_probs(probs) / math.log2(N)


def confidentiality(N: int, f: float, n_paths: int, k: int, path_len: int,
                    trials: int, rng: random.Random,
                    brute_force: bool = False) -> float:
    """Fraction of messages whose content stays confidential: an adversary
    must control relays on >= k of the n paths (and, without brute-force
    capability, also recover the interleaved fragment indices)."""
    ok = 0
    for _ in range(trials):
        malicious = set(rng.sample(range(N), int(f * N)))
        covered = 0
        for _ in range(n_paths):
            relays = rng.sample(range(N), path_len)
            if any(r in malicious for r in relays):
                covered += 1
        if covered < k:
            ok += 1
        elif not brute_force:
            # holds >= k cloves but path IDs differ: needs brute force
            ok += 1 if rng.random() < 0.98 else 0
    return ok / trials
