"""GF(2^8) arithmetic (AES polynomial 0x11B), numpy-vectorized.

Log/antilog tables over generator 3; element 0 handled explicitly.
Used by Rabin-IDA (ida.py) and Shamir secret sharing (shamir.py).
"""
from __future__ import annotations

import numpy as np

_POLY = 0x11B

EXP = np.zeros(512, np.uint8)
LOG = np.zeros(256, np.int32)
x = 1
for i in range(255):
    EXP[i] = x
    LOG[x] = i
    # multiply x by the generator 3:  3*x = (2*x) xor x
    x2 = (x << 1) ^ (_POLY if (x << 1) & 0x100 else 0)
    x = (x2 ^ x) & 0xFF
EXP[255:510] = EXP[:255]
LOG[0] = -512  # sentinel: anything + LOG[0] stays far negative


def mul(a, b):
    """Elementwise GF(256) product of uint8 arrays (broadcasting)."""
    a = np.asarray(a, np.uint8)
    b = np.asarray(b, np.uint8)
    la, lb = LOG[a], LOG[b]
    out = EXP[np.maximum(la + lb, 0) % 255]
    return np.where((a == 0) | (b == 0), np.uint8(0), out).astype(np.uint8)


def inv(a):
    a = np.asarray(a, np.uint8)
    if np.any(a == 0):
        raise ZeroDivisionError("GF(256) inverse of 0")
    return EXP[(255 - LOG[a]) % 255].astype(np.uint8)


def matmul(A: np.ndarray, B: np.ndarray) -> np.ndarray:
    """GF(256) matrix product: (m,k) @ (k,n) -> (m,n), XOR-accumulated."""
    A = np.asarray(A, np.uint8)
    B = np.asarray(B, np.uint8)
    m, k = A.shape
    k2, n = B.shape
    assert k == k2
    out = np.zeros((m, n), np.uint8)
    for j in range(k):  # k is small (the IDA threshold); vectorize over n
        out ^= mul(A[:, j][:, None], B[j][None, :])
    return out


def mat_inv(A: np.ndarray) -> np.ndarray:
    """Gauss-Jordan inverse of a (k,k) GF(256) matrix."""
    A = np.array(A, np.uint8)
    k = A.shape[0]
    aug = np.concatenate([A, np.eye(k, dtype=np.uint8)], axis=1)
    for col in range(k):
        piv = col + int(np.nonzero(aug[col:, col])[0][0])
        if aug[piv, col] == 0:
            raise np.linalg.LinAlgError("singular GF(256) matrix")
        aug[[col, piv]] = aug[[piv, col]]
        aug[col] = mul(aug[col], inv(aug[col, col]))
        for r in range(k):
            if r != col and aug[r, col]:
                aug[r] ^= mul(aug[r, col], aug[col])
    return aug[:, k:]
