"""Hash-Radix tree (HR-tree): the decentralized KV-cache index (§3.3).

Cuckoo-filter-inspired: tree nodes store *b-bit hashes* of variable-length
token chunks instead of the chunks themselves, so the aggregated KV-cache
state of every model node in a group fits in a compact structure that is
cheap to synchronize (each node periodically broadcasts its local subtree
as a list of hash paths).

Search (Algorithm 1): preprocess the prompt into chunk hashes using the
group's chunk-length array L (from the Sentry module), walk children by
hash, return (model-node pointers at the deepest matched node, depth d).
A match requires d >= tau_c; false-positive rate is (1/2^b)^d.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Iterable, Sequence

# polynomial rolling hash over token ids (mirrored by kernels/chunk_hash)
_HASH_MULT = 1_000_003
_HASH_SEED = 0x9E3779B9


def chunk_hash(tokens: Sequence[int], bits: int = 8,
               seed: int = _HASH_SEED) -> int:
    h = seed
    for t in tokens:
        h = (h * _HASH_MULT + int(t) + 1) & 0xFFFFFFFF
    # xor-fold 32 -> bits
    out = 0
    x = h
    while x:
        out ^= x & ((1 << bits) - 1)
        x >>= bits
    return out


def preprocess(tokens: Sequence[int], lengths: Sequence[int],
               bits: int = 8, default_chunk: int = 64) -> list[int]:
    """Variable-length chunking per L, then default_chunk for the tail."""
    hashes = []
    pos = 0
    n = len(tokens)
    for ln in lengths:
        if pos >= n or ln <= 0:
            break
        if pos + ln > n:
            break  # partial chunk: stop (prefix semantics)
        hashes.append(chunk_hash(tokens[pos:pos + ln], bits))
        pos += ln
    while pos + default_chunk <= n:
        hashes.append(chunk_hash(tokens[pos:pos + default_chunk], bits))
        pos += default_chunk
    return hashes


@dataclass
class _Node:
    children: dict = field(default_factory=dict)     # hash -> _Node
    holders: dict = field(default_factory=dict)      # node_id -> ts


class HRTree:
    """Aggregated view of the group's cached prefixes."""

    def __init__(self, lengths: Sequence[int], bits: int = 8,
                 default_chunk: int = 64):
        self.lengths = list(lengths)
        self.bits = bits
        self.default_chunk = default_chunk
        self.root = _Node()

    # ---- building ----
    def insert_hashes(self, hashes: Iterable[int], holder, ts=None):
        ts = time.monotonic() if ts is None else ts
        node = self.root
        for h in hashes:
            node = node.children.setdefault(h, _Node())
            node.holders[holder] = ts

    def insert_tokens(self, tokens: Sequence[int], holder, ts=None):
        self.insert_hashes(
            preprocess(tokens, self.lengths, self.bits, self.default_chunk),
            holder, ts)

    # ---- search (Algorithm 1) ----
    def search_hashes(self, hashes: Sequence[int], tau: int
                      ) -> tuple[list, int]:
        node, d = self.root, 0
        for h in hashes:
            child = node.children.get(h)
            if child is None:
                break
            node, d = child, d + 1
        if d < tau:
            return [], d
        return list(node.holders.keys()), d

    def search_tokens(self, tokens: Sequence[int], tau: int
                      ) -> tuple[list, int]:
        return self.search_hashes(
            preprocess(tokens, self.lengths, self.bits, self.default_chunk),
            tau)

    # ---- sync ----
    def export_paths(self, holder) -> list[list[int]]:
        """Hash paths this holder appears on (leaf-deep only) — what a model
        node broadcasts in state synchronization."""
        out = []

        def walk(node, prefix):
            leafish = True
            for h, ch in node.children.items():
                if holder in ch.holders:
                    leafish = False
                    walk(ch, prefix + [h])
            if leafish and prefix:
                out.append(prefix)

        walk(self.root, [])
        return out

    def merge_paths(self, paths: Iterable[Sequence[int]], holder, ts=None):
        for p in paths:
            self.insert_hashes(p, holder, ts)

    def remove_holder(self, holder):
        def walk(node):
            node.holders.pop(holder, None)
            dead = []
            for h, ch in node.children.items():
                walk(ch)
                if not ch.holders and not ch.children:
                    dead.append(h)
            for h in dead:
                node.children.pop(h)

        walk(self.root)

    def expire(self, before_ts: float):
        def walk(node):
            for nid, ts in list(node.holders.items()):
                if ts < before_ts:
                    node.holders.pop(nid)
            dead = []
            for h, ch in node.children.items():
                walk(ch)
                if not ch.holders and not ch.children:
                    dead.append(h)
            for h in dead:
                node.children.pop(h)

        walk(self.root)

    # ---- stats ----
    def size(self) -> int:
        n = 0
        stack = [self.root]
        while stack:
            nd = stack.pop()
            n += 1
            stack.extend(nd.children.values())
        return n

    def false_positive_rate(self, depth: int) -> float:
        return (1.0 / (1 << self.bits)) ** max(depth, 1)
