"""ChaCha20 stream cipher (RFC 8439), numpy-vectorized across blocks.

Stands in for AES in S-IDA (the paper says "symmetric encryption, such as
AES"; no crypto libraries ship in this container — see DESIGN.md
substitutions).  Vectorizing the 20 rounds across all 64-byte blocks of a
message gives multi-MB/s throughput in pure numpy.
"""
from __future__ import annotations

import os

import numpy as np

_CONST = np.frombuffer(b"expand 32-byte k", dtype="<u4").copy()


def _rotl(v, n):
    return (v << np.uint32(n)) | (v >> np.uint32(32 - n))


def _quarter(s, a, b, c, d):
    s[a] += s[b]
    s[d] ^= s[a]
    s[d] = _rotl(s[d], 16)
    s[c] += s[d]
    s[b] ^= s[c]
    s[b] = _rotl(s[b], 12)
    s[a] += s[b]
    s[d] ^= s[a]
    s[d] = _rotl(s[d], 8)
    s[c] += s[d]
    s[b] ^= s[c]
    s[b] = _rotl(s[b], 7)


def keystream(key: bytes, nonce: bytes, nblocks: int,
              counter: int = 0) -> np.ndarray:
    """(nblocks*64,) uint8 keystream."""
    assert len(key) == 32 and len(nonce) == 12
    k = np.frombuffer(key, "<u4")
    n = np.frombuffer(nonce, "<u4")
    state = np.zeros((16, nblocks), np.uint32)
    state[0:4] = _CONST[:, None]
    state[4:12] = k[:, None]
    state[12] = (counter + np.arange(nblocks)).astype(np.uint32)
    state[13:16] = n[:, None]
    w = state.copy()
    old = np.seterr(over="ignore")
    try:
        for _ in range(10):  # 10 double rounds = 20 rounds
            _quarter(w, 0, 4, 8, 12)
            _quarter(w, 1, 5, 9, 13)
            _quarter(w, 2, 6, 10, 14)
            _quarter(w, 3, 7, 11, 15)
            _quarter(w, 0, 5, 10, 15)
            _quarter(w, 1, 6, 11, 12)
            _quarter(w, 2, 7, 8, 13)
            _quarter(w, 3, 4, 9, 14)
        w += state
    finally:
        np.seterr(**old)
    # serialize: blocks are columns; little-endian words, word-major per block
    return w.T.astype("<u4").tobytes()


def xor_stream(data: bytes, key: bytes, nonce: bytes,
               counter: int = 0) -> bytes:
    nblocks = (len(data) + 63) // 64
    ks = np.frombuffer(keystream(key, nonce, nblocks, counter), np.uint8)
    buf = np.frombuffer(data, np.uint8) ^ ks[:len(data)]
    return buf.tobytes()


def encrypt(data: bytes, key: bytes, nonce: bytes | None = None) -> bytes:
    """nonce-prefixed ciphertext (nonce || body)."""
    nonce = nonce or os.urandom(12)
    return nonce + xor_stream(data, key, nonce, counter=1)


def decrypt(blob: bytes, key: bytes) -> bytes:
    nonce, body = blob[:12], blob[12:]
    return xor_stream(body, key, nonce, counter=1)
