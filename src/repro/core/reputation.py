"""Reputation scores with sliding-window punishment (§3.4).

  R(T) = alpha * R(T-1) + beta * C(T)                     (normal)
  R(T) = alpha * R(T-1) + (W+1)/(W + c/gamma + 2) * C(T)  (punished)

punishment applies when the fraction of abnormal C(T) values
(C < tau_abnormal) in the last W epochs exceeds gamma.  Paper settings:
alpha=0.4, beta=0.6, W=5, gamma=1/5, untrusted below 0.4.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field


@dataclass
class ReputationConfig:
    alpha: float = 0.4
    beta: float = 0.6
    window: int = 5
    gamma: float = 1.0 / 5.0
    tau_abnormal: float = 0.35     # C(T) below this counts as abnormal
    untrusted_below: float = 0.4
    initial: float = 0.6


@dataclass
class ReputationState:
    score: float
    history: deque = field(default_factory=lambda: deque(maxlen=64))

    def is_trusted(self, cfg: ReputationConfig) -> bool:
        return self.score >= cfg.untrusted_below


class ReputationTracker:
    def __init__(self, cfg: ReputationConfig = ReputationConfig()):
        self.cfg = cfg
        self.nodes: dict = {}

    def get(self, node_id) -> ReputationState:
        if node_id not in self.nodes:
            self.nodes[node_id] = ReputationState(self.cfg.initial)
        return self.nodes[node_id]

    def update(self, node_id, c_t: float) -> float:
        """Apply one epoch's average challenge score C(T)."""
        cfg = self.cfg
        st = self.get(node_id)
        st.history.append(c_t)
        recent = list(st.history)[-cfg.window:]
        c_abn = sum(1 for v in recent if v < cfg.tau_abnormal)
        frac = c_abn / cfg.window
        if frac > cfg.gamma:
            w = cfg.window
            weight = (w + 1) / (w + c_abn / cfg.gamma + 2)
            st.score = cfg.alpha * st.score + weight * c_t
        else:
            st.score = cfg.alpha * st.score + cfg.beta * c_t
        st.score = min(max(st.score, 0.0), 1.0)
        return st.score

    def trusted(self) -> set:
        return {n for n, st in self.nodes.items()
                if st.is_trusted(self.cfg)}
