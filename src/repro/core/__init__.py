"""GenTorrent/PlanetServe core: the paper's four contributions.

  anonymity overlay   sida, onion, ed25519, chacha, shamir, ida, gf256
  overlay forwarding  hrtree, sentry, forwarding
  verification        verification (JAX PPL), reputation, consensus, vrf
  metrics             anonymity
"""
