"""Pure-python Ed25519 (RFC 8032) + X25519 Diffie-Hellman.

Node identities, committee list signing, and onion-hop key agreement.
Reference-style implementation (extended coordinates, deterministic
nonces); speed is adequate for overlay control-plane traffic (~ms/op).
"""
from __future__ import annotations

import hashlib
import os

P = 2 ** 255 - 19
L = 2 ** 252 + 27742317777372353535851937790883648493
D = (-121665 * pow(121666, P - 2, P)) % P
SQRT_M1 = pow(2, (P - 1) // 4, P)

_BX = None
_BY = 4 * pow(5, P - 2, P) % P


def _recover_x(y, sign):
    xx = (y * y - 1) * pow(D * y * y + 1, P - 2, P)
    x = pow(xx, (P + 3) // 8, P)
    if (x * x - xx) % P != 0:
        x = x * SQRT_M1 % P
    if (x * x - xx) % P != 0:
        raise ValueError("invalid point")
    if x % 2 != sign:
        x = P - x
    return x


_BX = _recover_x(_BY, 0)
B = (_BX, _BY, 1, _BX * _BY % P)  # extended coords (X, Y, Z, T)
IDENT = (0, 1, 1, 0)


def _add(p, q):
    X1, Y1, Z1, T1 = p
    X2, Y2, Z2, T2 = q
    A = (Y1 - X1) * (Y2 - X2) % P
    Bv = (Y1 + X1) * (Y2 + X2) % P
    C = 2 * T1 * T2 * D % P
    Dv = 2 * Z1 * Z2 % P
    E, F, G, H = Bv - A, Dv - C, Dv + C, Bv + A
    return (E * F % P, G * H % P, F * G % P, E * H % P)


def _mul(s, p):
    q = IDENT
    while s > 0:
        if s & 1:
            q = _add(q, p)
        p = _add(p, p)
        s >>= 1
    return q


def _compress(p):
    X, Y, Z, _ = p
    zi = pow(Z, P - 2, P)
    x, y = X * zi % P, Y * zi % P
    return ((y | ((x & 1) << 255)).to_bytes(32, "little"))


def _decompress(b: bytes):
    v = int.from_bytes(b, "little")
    sign = v >> 255
    y = v & ((1 << 255) - 1)
    x = _recover_x(y, sign)
    return (x, y, 1, x * y % P)


def _h(*parts) -> int:
    h = hashlib.sha512()
    for p in parts:
        h.update(p)
    return int.from_bytes(h.digest(), "little")


class SigningKey:
    def __init__(self, seed: bytes | None = None):
        self.seed = seed or os.urandom(32)
        h = hashlib.sha512(self.seed).digest()
        a = int.from_bytes(h[:32], "little")
        a &= (1 << 254) - 8
        a |= 1 << 254
        self._a = a
        self._prefix = h[32:]
        self.public = _compress(_mul(a, B))

    def sign(self, msg: bytes) -> bytes:
        r = _h(self._prefix, msg) % L
        R = _compress(_mul(r, B))
        k = _h(R, self.public, msg) % L
        s = (r + k * self._a) % L
        return R + s.to_bytes(32, "little")


def verify(public: bytes, msg: bytes, sig: bytes) -> bool:
    try:
        A = _decompress(public)
        R = _decompress(sig[:32])
        s = int.from_bytes(sig[32:], "little")
        if s >= L:
            return False
        k = _h(sig[:32], public, msg) % L
        lhs = _mul(s, B)
        rhs = _add(R, _mul(k, A))
        return _compress(lhs) == _compress(rhs)
    except Exception:
        return False


# --------------------------------------------------------------------------
# X25519 (Montgomery ladder) for onion-hop key agreement
# --------------------------------------------------------------------------

def _x25519_clamp(k: bytes) -> int:
    a = bytearray(k)
    a[0] &= 248
    a[31] &= 127
    a[31] |= 64
    return int.from_bytes(bytes(a), "little")


def x25519(scalar: bytes, point: bytes = None) -> bytes:
    k = _x25519_clamp(scalar)
    u = 9 if point is None else int.from_bytes(point, "little") & ((1 << 255) - 1)
    x1, x2, z2, x3, z3 = u, 1, 0, u, 1
    swap = 0
    for t in range(254, -1, -1):
        bit = (k >> t) & 1
        if swap ^ bit:
            x2, x3 = x3, x2
            z2, z3 = z3, z2
        swap = bit
        A = (x2 + z2) % P
        Bv = (x2 - z2) % P
        AA = A * A % P
        BB = Bv * Bv % P
        E = (AA - BB) % P
        C = (x3 + z3) % P
        Dv = (x3 - z3) % P
        DA = Dv * A % P
        CB = C * Bv % P
        x3 = (DA + CB) % P
        x3 = x3 * x3 % P
        z3 = (DA - CB) % P
        z3 = x1 * z3 * z3 % P
        x2 = AA * BB % P
        z2 = E * (AA + 121665 * E) % P
    if swap:
        x2, x3 = x3, x2
        z2, z3 = z3, z2
    out = x2 * pow(z2, P - 2, P) % P
    return out.to_bytes(32, "little")


def dh_keypair(seed: bytes | None = None):
    sk = seed or os.urandom(32)
    return sk, x25519(sk)


def dh_shared(sk: bytes, peer_pub: bytes) -> bytes:
    return hashlib.sha256(x25519(sk, peer_pub)).digest()
