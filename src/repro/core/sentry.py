"""Sentry: derives the chunk-length array L from detected common system
prompts (Appendix A3).

Collect incoming requests, find frequent shared prefixes (a counting trie
over token ids, sampled), take the distinct common-prefix lengths
S = s_1 < s_2 < ... < s_n, and build

    l_1      = s_1
    l_{2i}   = delta
    l_{2i+1} = s_{i+1} - s_i - delta

so each detected system prompt ends exactly at a chunk boundary, separated
by a small delta chunk — the first HR-tree levels then route on shared
system prompts (cache affinity), per the paper.  Refreshed every
``refresh_every`` requests (10,000 in the paper's evaluation).
"""
from __future__ import annotations

from collections import Counter
from dataclasses import dataclass
from typing import Sequence


@dataclass
class SentryConfig:
    delta: int = 8
    min_support: int = 8          # occurrences before a prefix is "common"
    min_len: int = 16             # ignore very short common prefixes
    max_probe: int = 4096         # cap prefix scan length
    probe_stride: int = 16        # granularity of prefix-length probing
    refresh_every: int = 10_000
    max_prompts: int = 8          # n distinct system prompts tracked


class Sentry:
    def __init__(self, cfg: SentryConfig = SentryConfig()):
        self.cfg = cfg
        self._buffer: list[tuple] = []
        self._count = 0
        self.lengths: list[int] = []      # the array L

    def observe(self, tokens: Sequence[int]):
        self._count += 1
        if len(self._buffer) < 4096:
            self._buffer.append(tuple(tokens[: self.cfg.max_probe]))
        if self._count % self.cfg.refresh_every == 0:
            self.refresh()

    def refresh(self):
        self.lengths = build_lengths(self.detect_prompt_lengths(),
                                     self.cfg.delta)
        self._buffer.clear()

    def detect_prompt_lengths(self) -> list[int]:
        """Distinct common-prefix lengths, ascending."""
        cfg = self.cfg
        if len(self._buffer) < cfg.min_support:
            return []
        found = {}
        # probe prefix lengths at stride granularity
        for ln in range(cfg.min_len, cfg.max_probe + 1, cfg.probe_stride):
            c = Counter(t[:ln] for t in self._buffer if len(t) >= ln)
            for prefix, cnt in c.items():
                if cnt >= cfg.min_support:
                    found[prefix[: cfg.min_len]] = max(
                        found.get(prefix[: cfg.min_len], 0), ln)
        lengths = sorted(set(found.values()))
        return lengths[: cfg.max_prompts]


def build_lengths(s: Sequence[int], delta: int) -> list[int]:
    """The paper's equations (A3): [s1, d, s2-s1-d, d, s3-s2-d, ...]."""
    s = [x for x in sorted(set(s)) if x > 0]
    if not s:
        return []
    L = [s[0]]
    for prev, cur in zip(s, s[1:]):
        gap = cur - prev - delta
        if gap <= 0:      # prompts closer than delta: merge boundaries
            L.append(cur - prev)
            continue
        L.extend([delta, gap])
    return L
