"""S-IDA: Secure Information Dispersal (Krawczyk '93), exactly the paper's
recipe (§3.2):

  1. encrypt M under a fresh symmetric key K           (ChaCha20 here)
  2. split {M}_K into n fragments with k-threshold Rabin IDA
  3. split K into n shares with k-threshold Shamir SSS
  4. clove_i = (i, M_i, K_i); send each clove on a distinct path
  5. any k cloves recover K (SSS) then M (IDA + decrypt)

< k cloves: the key shares reveal nothing (information-theoretic) and the
IDA fragments are ciphertext slices.
"""
from __future__ import annotations

import os
from dataclasses import dataclass

from repro.core import chacha, ida, shamir


@dataclass(frozen=True)
class Clove:
    index: int          # 0-based fragment index
    frag: bytes         # Rabin-IDA fragment of {M}_K
    key_share: bytes    # Shamir share of K (x = index+1)
    n: int
    k: int

    def encode(self) -> bytes:
        import struct
        return (struct.pack("<BBBH", self.n, self.k, self.index,
                            len(self.key_share))
                + self.key_share + self.frag)

    @staticmethod
    def decode(blob: bytes) -> "Clove":
        import struct
        n, k, ix, klen = struct.unpack("<BBBH", blob[:5])
        return Clove(ix, blob[5 + klen:], blob[5:5 + klen], n, k)


def make_cloves(message: bytes, n: int, k: int, key: bytes | None = None
                ) -> list[Clove]:
    key = key or os.urandom(32)
    ct = chacha.encrypt(message, key)
    frags = ida.split(ct, n, k)
    shares = shamir.split(key, n, k)
    return [Clove(i, frags[i][1], shares[i][1], n, k) for i in range(n)]


def recover(cloves: list[Clove]) -> bytes:
    assert cloves, "no cloves"
    n, k = cloves[0].n, cloves[0].k
    uniq = {c.index: c for c in cloves}
    cs = list(uniq.values())
    if len(cs) < k:
        raise ValueError(f"need {k} cloves, have {len(cs)}")
    key = shamir.combine([(c.index + 1, c.key_share) for c in cs], k)
    ct = ida.combine([(c.index, c.frag) for c in cs], n, k)
    return chacha.decrypt(ct, key)
