"""Verifiable random function from deterministic Ed25519 signatures.

beta = SHA512(sig(sk, alpha)); proof = the signature.  Uniqueness of honest
Ed25519 signatures makes the output unpredictable-but-verifiable — the
construction the committee uses for epoch leader election (§3.4): the seed
alpha is the final commit hash of the previous epoch.
"""
from __future__ import annotations

import hashlib

from repro.core import ed25519


def prove(sk: ed25519.SigningKey, alpha: bytes) -> tuple[bytes, bytes]:
    proof = sk.sign(b"vrf:" + alpha)
    beta = hashlib.sha512(proof).digest()
    return beta, proof


def verify(public: bytes, alpha: bytes, beta: bytes, proof: bytes) -> bool:
    if not ed25519.verify(public, b"vrf:" + alpha, proof):
        return False
    return hashlib.sha512(proof).digest() == beta


def leader_index(seeds: list[bytes], n: int) -> int:
    """Deterministic index from committee-agreed randomness."""
    h = hashlib.sha256(b"".join(seeds)).digest()
    return int.from_bytes(h[:8], "big") % n
