"""Roofline CLI: render the three-term table from dry-run artifacts.

    PYTHONPATH=src python -m repro.launch.roofline [--dir results/dryrun/pod16x16]
        [--baseline results/dryrun_baseline/pod16x16] [--json out.json]
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun/pod16x16")
    ap.add_argument("--baseline", default="")
    ap.add_argument("--json", default="")
    args = ap.parse_args()

    sys.path.insert(0, str(Path(__file__).resolve().parents[3]))
    from benchmarks.bench_roofline import build_table

    rows = build_table(args.dir)
    base = {}
    if args.baseline:
        base = {(r["arch"], r["shape"]): r
                for r in build_table(args.baseline)}
    hdr = (f"{'arch':<22} {'shape':<12} {'t_comp':>9} {'t_mem':>9} "
           f"{'t_coll':>9} {'dom':<5} {'useful':>6} {'HBM/dev':>8}")
    if base:
        hdr += "  vs-baseline"
    print(hdr)
    for r in rows:
        if r["status"] != "ok":
            print(f"{r['arch']:<22} {r['shape']:<12} skipped: "
                  f"{r.get('reason','')[:60]}")
            continue
        line = (f"{r['arch']:<22} {r['shape']:<12} "
                f"{r['t_compute_s']:>9.3g} {r['t_memory_s']:>9.3g} "
                f"{r['t_collective_s']:>9.3g} {r['dominant'][:4]:<5} "
                f"{r['useful_flops_ratio']:>6.2f} "
                f"{r['hbm_per_dev_gb']:>7.1f}G")
        b = base.get((r["arch"], r["shape"]))
        if b and b.get("status") == "ok":
            bmax = max(b["t_compute_s"], b["t_memory_s"],
                       b["t_collective_s"])
            vmax = max(r["t_compute_s"], r["t_memory_s"],
                       r["t_collective_s"])
            line += f"  {bmax/max(vmax,1e-12):>6.1f}x"
        print(line)
    if args.json:
        Path(args.json).write_text(json.dumps(rows, indent=1))


if __name__ == "__main__":
    main()
