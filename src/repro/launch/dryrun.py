import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import (jax locks the device
# count on first init).  512 placeholder host devices back the production
# meshes: 16x16 single-pod and 2x16x16 multi-pod.

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from pathlib import Path  # noqa: E402

import jax           # noqa: E402

from repro.configs import base as cfgbase                    # noqa: E402
from repro.distributed import collectives, hlo_analysis, sharding  # noqa: E402
from repro.launch.mesh import make_production_mesh           # noqa: E402
from repro.models.lm import build_model                      # noqa: E402
from repro.training import optimizer as opt_lib              # noqa: E402
from repro.training.train_step import make_train_step        # noqa: E402


def _mem_analysis(compiled):
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(ma, f, None)
        if v is not None:
            out[f] = int(v)
    return out


def _cost_analysis(compiled):
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and
                (k in ("flops", "bytes accessed", "optimal_seconds")
                 or k.startswith("bytes accessed"))}
    except Exception:
        return {}


def build_step(cfg, shape, mesh):
    """Returns (jitted_fn, arg_specs) for the cell's step function."""
    model = build_model(cfg)
    specs = cfgbase.input_specs(cfg, shape)
    in_sh = sharding.input_shardings(cfg, specs, mesh)

    if shape.kind == "train":
        adamw = opt_lib.AdamWConfig()
        step_fn = make_train_step(cfg, model, adamw)
        p_spec = model.param_specs()
        o_spec = jax.eval_shape(opt_lib.init_state, p_spec)
        p_sh = sharding.param_shardings(cfg, p_spec, mesh, train=True)
        o_sh = {"mu": sharding.param_shardings(cfg, p_spec, mesh, True),
                "nu": sharding.param_shardings(cfg, p_spec, mesh, True),
                "step": jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec())}
        fn = jax.jit(step_fn,
                     in_shardings=(p_sh, o_sh, in_sh),
                     out_shardings=(p_sh, o_sh, None),
                     donate_argnums=(0, 1))
        return fn, (p_spec, o_spec, specs)

    # serving path: bf16 params, no FSDP (weights sharded on model axis only)
    scfg = dataclasses.replace(cfg, param_dtype="bfloat16")
    smodel = build_model(scfg)
    p_spec = smodel.param_specs()
    p_sh = sharding.param_shardings(scfg, p_spec, mesh, train=False)

    if shape.kind == "prefill":
        def prefill_fn(params, batch):
            aux = {k: v for k, v in batch.items() if k != "tokens"}
            return smodel.prefill(params, batch["tokens"], aux=aux or None,
                                  max_len=shape.seq_len)
        c_spec = _cache_spec(scfg, smodel, shape)
        c_sh = sharding.cache_shardings(
            scfg, c_spec, mesh, long_ctx=shape.name == "long_500k")
        fn = jax.jit(prefill_fn, in_shardings=(p_sh, in_sh),
                     out_shardings=(None, c_sh))
        return fn, (p_spec, specs)

    # decode: one new token against a cache of seq_len
    c_spec = _cache_spec(scfg, smodel, shape)
    c_sh = sharding.cache_shardings(
        scfg, c_spec, mesh, long_ctx=shape.name == "long_500k")

    def decode_fn(params, cache, batch):
        return smodel.decode(params, cache, batch["tokens"], batch["pos"])

    fn = jax.jit(decode_fn, in_shardings=(p_sh, c_sh, in_sh),
                 out_shardings=(None, c_sh), donate_argnums=(1,))
    return fn, (p_spec, c_spec, specs)


def _cache_spec(cfg, model, shape):
    T_mem = 0
    if cfg.is_encdec:
        T_mem = shape.seq_len // 2
    elif cfg.n_image_tokens:
        T_mem = cfg.n_image_tokens
    return model.cache_specs(shape.global_batch, shape.seq_len, T_mem)


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             force: bool = False) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    out_path = out_dir / mesh_name / f"{arch}_{shape_name}.json"
    out_path.parent.mkdir(parents=True, exist_ok=True)
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    cfg = cfgbase.get_config(arch)
    shape = cfgbase.SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind, "seq_len": shape.seq_len,
           "global_batch": shape.global_batch}
    runnable, reason = cfgbase.cell_is_runnable(cfg, shape)
    if not runnable:
        rec.update(status="skipped", reason=reason)
        out_path.write_text(json.dumps(rec, indent=1))
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    try:
        from repro.distributed.constraints import activation_mesh
        t0 = time.time()
        with mesh, activation_mesh(mesh):
            fn, arg_specs = build_step(cfg, shape, mesh)
            lowered = fn.lower(*arg_specs)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        ca = _cost_analysis(compiled)
        ma = _mem_analysis(compiled)
        hlo = compiled.as_text()
        # trip-count-aware per-device flops/bytes/collectives (XLA's own
        # cost_analysis counts while bodies once; see hlo_analysis.py)
        hla = hlo_analysis.analyze(hlo, n_dev)
        coll = collectives.collective_stats(hlo, n_dev)  # unscaled x-check
        counts = cfg.param_counts()
        rec.update(
            status="ok",
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            n_devices=int(n_dev),
            cost_analysis=ca, memory_analysis=ma,
            hlo_analysis=hla, collectives_unscaled=coll,
            params_total=counts["total"], params_active=counts["active"],
            hlo_bytes=len(hlo),
        )
    except Exception as e:  # a failing cell is a bug in our sharding
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(cfgbase.SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    rec = run_cell(args.arch, args.shape, args.multi_pod, Path(args.out),
                   args.force)
    brief = {k: rec.get(k) for k in
             ("arch", "shape", "mesh", "status", "compile_s", "error")}
    if rec.get("status") == "ok":
        h = rec.get("hlo_analysis", {})
        print(json.dumps({**brief,
                          "flops_per_dev": h.get("flops"),
                          "bytes_per_dev": h.get("bytes"),
                          "coll_eff_bytes_per_dev": h.get("coll_eff_bytes"),
                          "mem": rec.get("memory_analysis", {})},
                         default=str))
        # the two artifacts the brief asks to print:
        print("memory_analysis:", rec.get("memory_analysis"))
        print("cost_analysis:", rec.get("cost_analysis"))
    else:
        print(json.dumps(brief))
        if rec.get("status") == "error":
            print(rec.get("traceback", ""))
            raise SystemExit(1)


if __name__ == "__main__":
    main()
