"""Serving driver: run a GenTorrent overlay serving a workload, on either
the deterministic simulator (default) or the localhost TCP transport.

    PYTHONPATH=src python -m repro.launch.serve --requests 100 --rate 2 \
        --workload Mixed --mode full
"""
from __future__ import annotations

import argparse
import json



def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=100)
    ap.add_argument("--rate", type=float, default=2.0)
    ap.add_argument("--workload", default="Mixed",
                    choices=["ToolUse", "Coding", "LongQA", "Mixed"])
    ap.add_argument("--mode", default="full",
                    choices=["full", "lb_only", "none"],
                    help="overlay forwarding mode (Fig 16 ablation)")
    ap.add_argument("--models", type=int, default=8)
    ap.add_argument("--users", type=int, default=24)
    args = ap.parse_args()

    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parents[3]))
    from benchmarks.serving_sim import run_serving_sim

    out = run_serving_sim(args.workload, args.mode, args.rate,
                          n_requests=args.requests,
                          n_users=args.users, n_models=args.models)
    print(json.dumps(out, indent=1, default=str))


if __name__ == "__main__":
    main()
