"""End-to-end training driver: data pipeline -> train_step -> checkpoints,
with fault-tolerant supervision and elastic re-mesh.

On this CPU container it trains reduced/small configs for real (the
examples use it to train a ~100M model for a few hundred steps); on a TPU
cluster the same driver runs the full configs — the mesh comes from
launch/mesh.py and every step function is the one the dry-run compiles.

  PYTHONPATH=src python -m repro.launch.train --arch gentorrent-llama3-8b \
      --reduced --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ck
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import base as cfgbase
from repro.models.lm import build_model
from repro.training import checkpoint as ckpt_lib
from repro.training import compression, optimizer as opt_lib
from repro.training.data import MarkovCorpus
from repro.training.train_step import make_train_step


def build_small_cfg(arch: str, d_model: int = 0, layers: int = 0):
    cfg = cfgbase.get_config(arch)
    red = cfg.reduced()
    kw = {}
    if d_model:
        kw.update(d_model=d_model, d_head=d_model // red.n_heads)
    if layers:
        assert layers % len(red.pattern) == 0
        kw.update(n_layers=layers)
    return dataclasses.replace(red, **kw) if kw else red


def train(arch: str, steps: int, batch: int, seq: int, ckpt_dir: str,
          d_model: int = 0, layers: int = 0, lr: float = 3e-3,
          resume: bool = True, compress: bool = False,
          microbatches: int = 1, log_every: int = 10,
          fail_at_step: int = -1) -> dict:
    cfg = build_small_cfg(arch, d_model, layers)
    model = build_model(cfg)
    adamw = opt_lib.AdamWConfig(lr=lr, warmup_steps=max(10, steps // 20),
                                total_steps=steps)

    err_state = None
    if compress:
        p_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        err_state = compression.init_error_state(p_shape)

        def compress_grads(grads):
            nonlocal err_state
            g, err_state = compression.compress_int8_ef(grads, err_state)
            return g
    else:
        compress_grads = None

    step_fn = jax.jit(make_train_step(cfg, model, adamw,
                                      microbatches=microbatches,
                                      compress_grads=compress_grads,
                                      block_q=min(256, seq)))

    params = model.init(jax.random.PRNGKey(0))
    opt_state = opt_lib.init_state(params)
    start = 0
    if resume and ckpt_dir:
        last = ckpt_lib.latest_step(ckpt_dir)
        if last is not None:
            (params, opt_state), start = ckpt_lib.restore(
                ckpt_dir, last, (params, opt_state))
            print(f"resumed from step {start}")

    corpus = MarkovCorpus(cfg.vocab, seed=0)
    losses = []
    t0 = time.time()
    tokens_done = 0
    it = corpus.batches(batch, seq, steps, seed=100 + start)
    for i, b in zip(range(start, steps), it):
        if i == fail_at_step:
            raise RuntimeError(f"injected failure at step {i}")
        batch_j = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt_state, m = step_fn(params, opt_state, batch_j)
        loss = float(m["loss"])
        losses.append(loss)
        tokens_done += batch * seq
        if ckpt_dir and (i + 1) % 50 == 0:
            ckpt_lib.save(ckpt_dir, i + 1, (params, opt_state))
            ckpt_lib.prune(ckpt_dir, keep=2)
        if (i + 1) % log_every == 0:
            tps = tokens_done / (time.time() - t0)
            print(f"step {i+1:>5} loss {loss:.4f} "
                  f"({tps:,.0f} tok/s)")
    if ckpt_dir:
        ckpt_lib.save(ckpt_dir, steps, (params, opt_state))
    return {"final_loss": losses[-1] if losses else None,
            "first_loss": losses[0] if losses else None,
            "losses": losses, "params": params, "cfg": cfg,
            "tokens_per_s": tokens_done / max(time.time() - t0, 1e-9)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gentorrent-llama3-8b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--fail-at-step", type=int, default=-1)
    args = ap.parse_args()
    out = train(args.arch, args.steps, args.batch, args.seq, args.ckpt_dir,
                d_model=args.d_model, layers=args.layers, lr=args.lr,
                compress=args.compress, microbatches=args.microbatches,
                fail_at_step=args.fail_at_step)
    print(json.dumps({k: v for k, v in out.items()
                      if k in ("final_loss", "first_loss", "tokens_per_s")}))


if __name__ == "__main__":
    main()
