"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16 x 16 = 256 chips; multi-pod:
2 pods x 256 = 512 chips with a leading "pod" axis (pure DP across pods —
DCN-class links; FSDP/TP stay inside a pod on ICI).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


def batch_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
