"""Fig 10: message confidentiality vs fraction of malicious nodes, with
and without brute-force-capable adversaries."""
from __future__ import annotations

import random
import time

from benchmarks.common import SCALE, emit, save
from repro.core.anonymity import confidentiality


def main():
    N = int(10_000 * max(SCALE, 0.05))
    trials = max(50, int(400 * SCALE))
    fracs = [0.01, 0.02, 0.05, 0.10]
    rows = []
    t0 = time.perf_counter()
    for f in fracs:
        rng = random.Random(7)
        no_bf = confidentiality(N, f, n_paths=4, k=3, path_len=3,
                                trials=trials, rng=rng, brute_force=False)
        bf = confidentiality(N, f, n_paths=4, k=3, path_len=3,
                             trials=trials, rng=rng, brute_force=True)
        rows.append({"f": f, "no_bruteforce": round(no_bf, 4),
                     "bruteforce": round(bf, 4)})
    us = (time.perf_counter() - t0) * 1e6 / (len(fracs) * trials * 2)
    save("fig10_confidentiality", {"N": N, "trials": trials, "rows": rows})
    emit("fig10_confidentiality_trial", us,
         {"rows": rows, "paper_f0.10_bf": 0.88})
    return rows


if __name__ == "__main__":
    main()
