"""Shared ground-truth model for the verification benchmarks (Figs 11/12,
§5.4): a tiny LM trained on the structured Markov corpus, plus the four
degraded impostors of §4.3:

  GT  trained model (stands in for Meta-Llama-3.1-8B-Instruct-Q4_0)
  m1  mild weight quantization        (Llama-3.2-3B-Q4_K_M stand-in)
  m2  harsh weight quantization       (Llama-3.2-1B-Q4_K_M)
  m3  harsh quantization + noise      (Llama-3.2-1B-Q4_K_S)
  m4  mild quantization + noise       (Llama-3.2-3B-Q4_K_S)

The stand-ins reproduce the *ordering* GT > m1/m4 > m2/m3 that drives the
paper's credit-score separation; the absolute models differ (CPU-only
container — DESIGN.md substitutions).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.configs import base
from repro.models.lm import build_model
from repro.training import optimizer as opt_lib
from repro.training.data import MarkovCorpus
from repro.training.train_step import make_train_step


@functools.lru_cache(maxsize=1)
def trained_gt(steps: int = 150):
    cfg = base.get_config("gentorrent-llama3-8b").reduced()
    cfg = dataclasses.replace(cfg, vocab=256, d_model=96, d_head=24)
    model = build_model(cfg)
    adamw = opt_lib.AdamWConfig(lr=5e-3, warmup_steps=10, total_steps=steps)
    step = jax.jit(make_train_step(cfg, model, adamw, block_q=32))
    params = model.init(jax.random.PRNGKey(0))
    opt = opt_lib.init_state(params)
    corpus = MarkovCorpus(cfg.vocab, seed=0, branching=2, noise=0.02)
    for b in corpus.batches(16, 48, steps):
        params, opt, _ = step(params, opt,
                              {k: jnp.asarray(v) for k, v in b.items()})
    return cfg, model, params, corpus


def _quantize(params, levels, noise=0.0, seed=1):
    key = jax.random.PRNGKey(seed)

    def q(x):
        if x.ndim < 2:
            return x
        s = jnp.max(jnp.abs(x)) + 1e-9
        y = jnp.round(x / s * levels) / levels * s
        if noise:
            nonlocal key
            key, k2 = jax.random.split(key)
            y = y + noise * s * jax.random.normal(k2, y.shape)
        return y
    return jax.tree.map(q, params)


def impostors(params):
    """Degradation ladder: m1/m4 mild (3B-class stand-ins), m2/m3 harsh
    (1B-class).  Calibrated so the mild pair sits near the abnormal
    threshold and the harsh pair well below it (paper Fig 11/12)."""
    return {
        "m1": _quantize(params, levels=4, noise=0.02),
        "m2": _quantize(params, levels=2, noise=0.10),
        "m3": _quantize(params, levels=1, noise=0.08),
        "m4": _quantize(params, levels=4, noise=0.04),
    }


def greedy(model, params, prompt, n=16):
    toks = list(prompt)
    logits, cache = jax.jit(
        lambda p, t: model.prefill(p, t, max_len=len(toks) + n + 2,
                                   block_q=16))(
        params, jnp.asarray([toks], jnp.int32))
    dec = jax.jit(model.decode)
    out = []
    pos = len(toks)
    for _ in range(n):
        nxt = int(jnp.argmax(logits[0]))
        out.append(nxt)
        logits, cache = dec(params, cache, jnp.asarray([[nxt]], jnp.int32),
                            jnp.asarray([pos], jnp.int32))
        pos += 1
    return out
