"""Fig 9: normalized entropy anonymity vs fraction of malicious nodes,
for GenTorrent / onion / garlic-cast in a 10,000-node network."""
from __future__ import annotations

import random
import time

from benchmarks.common import SCALE, emit, save
from repro.core import anonymity


def main():
    N = int(10_000 * max(SCALE, 0.05))
    trials = max(10, int(60 * SCALE))
    fracs = [0.01, 0.05, 0.10, 0.15, 0.20]
    rows = []
    t0 = time.perf_counter()
    for f in fracs:
        rng = random.Random(42)
        gt = sum(anonymity.gentorrent_anonymity(N, f, 4, 3, rng)
                 for _ in range(trials)) / trials
        on = sum(anonymity.onion_anonymity(N, f, 3, rng)
                 for _ in range(trials)) / trials
        gc = sum(anonymity.garlic_anonymity(N, f, 4, 3, rng)
                 for _ in range(trials)) / trials
        rows.append({"f": f, "gentorrent": round(gt, 4),
                     "onion": round(on, 4), "garlic_cast": round(gc, 4)})
    us = (time.perf_counter() - t0) * 1e6 / (len(fracs) * trials * 3)
    save("fig9_anonymity", {"N": N, "trials": trials, "rows": rows})
    emit("fig9_anonymity_trial", us,
         {"rows": rows, "paper_f0.05": {"gentorrent": 0.965, "onion": 0.954,
                                        "gc": 0.903}})
    return rows


if __name__ == "__main__":
    main()
