"""Fig 15 + Fig 16: serving latency (Avg / P99 / TTFT) with vs without
HR-tree forwarding across the four workloads, plus the ablation
(none -> +HR-tree -> +HR-tree+LB)."""
from __future__ import annotations

import time

from benchmarks.common import SCALE, emit, save
from benchmarks.serving_sim import run_serving_sim


def main():
    n_req = max(40, int(120 * SCALE))
    rate = 2.0
    rows = []
    t0 = time.perf_counter()
    for wl in ("ToolUse", "Coding", "LongQA", "Mixed"):
        with_tree = run_serving_sim(wl, "full", rate, n_req, seed=1)
        without = run_serving_sim(wl, "none", rate, n_req, seed=1)
        rows.append({"workload": wl, "gentorrent": with_tree,
                     "no_hrtree": without})
    # Fig 16 ablation on ToolUse
    ablation = {m: run_serving_sim("ToolUse", m, rate, n_req, seed=2)
                for m in ("none", "lb_only", "full")}
    us = (time.perf_counter() - t0) * 1e6 / (len(rows) * 2 + 3)
    save("fig15_serving_latency", {"rows": rows})
    save("fig16_ablation", ablation)
    derived = {r["workload"]: {
        "ttft_gain": (r["no_hrtree"]["ttft_s"] or 0)
        / max(r["gentorrent"]["ttft_s"] or 1e-9, 1e-9),
        "avg_gain": (r["no_hrtree"]["avg_latency_s"] or 0)
        / max(r["gentorrent"]["avg_latency_s"] or 1e-9, 1e-9)}
        for r in rows}
    emit("fig15_serving_sim", us, derived)
    emit("fig16_ablation_avg_latency", us,
         {m: ablation[m]["avg_latency_s"] for m in ablation})
    return rows, ablation


if __name__ == "__main__":
    main()
