"""Prefix-affinity overlay forwarding vs load-only routing (PR 3).

Shared-prompt workload over 2+ model nodes on SimNet, each with its own
paged RealEngine: G prompt groups, one seed request per group followed by
S sibling requests sharing the group's prefix.  Siblings enter the
overlay at a NON-holder node whose (stale) sync view shows every peer
moderately busy — the regime where load-only routing keeps them local
and re-prefills the shared prefix from scratch, while sketch-based
affinity routing forwards them to the prefix holder where admission
aliases the cached pages and chunk-prefills only the divergence tail,
one batched dispatch per admission round.

Reported per mode: multi-node generated tokens/s (wall clock over the
sibling phase), total + duplicate prefill tokens and KV bytes, and
prefill dispatch counts.  The duplicate-prefill and dispatch counters
are deterministic (token counts, not timings) — scripts/check_bench.py
gates them against results/bench/baseline/ in CI.
"""
from __future__ import annotations

import sys
import time

from benchmarks.common import emit, save


def _build_nodes(n_models, cfg, model, params, affinity):
    from repro.core.forwarding import ForwardingConfig
    from repro.net.simnet import SimNet
    from repro.overlay.model_node import ModelNode
    from repro.serving.engine import RealEngine

    net = SimNet(seed=7)
    fwd = ForwardingConfig(affinity=affinity)
    nodes = [ModelNode(f"m{i}", use_crypto=False, fwd_cfg=fwd,
                       real_engine=RealEngine(cfg, model, params,
                                              max_len=256))
             for i in range(n_models)]
    for nd in nodes:
        net.add_node(nd.node_id, nd)
    members = [nd.node_id for nd in nodes]
    for nd in nodes:
        nd.join_group(members)
    return net, nodes


def _run_mode(affinity: bool, n_models: int, n_groups: int, siblings: int,
              shared_len: int, tail_len: int, max_new: int):
    import jax

    from repro.configs import base
    from repro.models.lm import build_model
    from repro.overlay.probe import ResponseSink, direct_payload
    from repro.serving.prefix_cache import BLOCK

    cfg = base.get_config("gentorrent-llama3-8b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    net, nodes = _build_nodes(n_models, cfg, model, params, affinity)
    sink = ResponseSink()
    net.add_node("sink", sink)

    shared = {g: [(11 * (g + 1) + j) % cfg.vocab for j in range(shared_len)]
              for g in range(n_groups)}
    # seed phase: one request per group, pinned to its holder (also warms
    # every jit trace so the timed sibling phase is compile-free)
    for g in range(n_groups):
        holder = nodes[g % n_models]
        holder._process(net, direct_payload(f"seed{g}", shared[g] + [1] * tail_len,
                                      max_new), forwarded=True)
    net.run_until(net.t + 60)
    for nd in nodes:
        nd.broadcast_state(net)
    net.run_until(net.t + 5)
    # stale sync view: every peer looks moderately busy (under the
    # affinity load bound even after the per-forward optimistic echo,
    # over the load-balance preference for an idle self) — the contended
    # regime the paper routes in
    for nd in nodes:
        for pid, p in nd.peers.items():
            if pid != nd.node_id:
                p.active_requests = 3

    pre_tokens = {nd.node_id: nd.real_engine.prefill_tokens for nd in nodes}
    pre_disp = {nd.node_id: nd.real_engine.prefill_dispatches for nd in nodes}
    n_sib = 0
    for g in range(n_groups):
        entry = nodes[(g + 1) % n_models]
        for s in range(siblings):
            toks = shared[g] + [50 + 7 * s] * tail_len
            net.call_after(0.01, entry._process, net,
                           direct_payload(f"g{g}s{s}", toks, max_new))
            n_sib += 1
    t0 = time.perf_counter()
    net.run_until(net.t + 120)
    wall = time.perf_counter() - t0

    sib_outputs = [v for k, v in sink.got.items() if k.startswith("g")]
    gen_tokens = sum(len(o) for o in sib_outputs)
    prefill_tokens = sum(nd.real_engine.prefill_tokens
                         - pre_tokens[nd.node_id] for nd in nodes)
    dispatches = sum(nd.real_engine.prefill_dispatches
                     - pre_disp[nd.node_id] for nd in nodes)
    # ideal sibling prefill = divergence tail only (the block-aligned
    # shared prefix is cached somewhere in the group after its seed)
    aligned = (shared_len // BLOCK) * BLOCK
    ideal = n_sib * (shared_len - aligned + tail_len)
    token_bytes = nodes[0].real_engine.page_bytes // BLOCK
    return {
        "completed": len(sib_outputs),
        "generated_tokens": gen_tokens,
        "wall_s": wall,
        "tok_s": gen_tokens / wall if wall > 0 else 0.0,
        "prefill_tokens": prefill_tokens,
        "duplicate_prefill_tokens": prefill_tokens - ideal,
        "duplicate_prefill_kv_bytes": (prefill_tokens - ideal) * token_bytes,
        "prefill_dispatches": dispatches,
        "forwarded": sum(nd.metrics["forwarded_out"] for nd in nodes),
        "affinity_hits": sum(nd.metrics["affinity_hits"] for nd in nodes),
    }


def bench_affinity(n_models: int = 3, n_groups: int = 3, siblings: int = 3,
                   shared_len: int = 96, tail_len: int = 8,
                   max_new: int = 8) -> dict:
    params = {"n_models": n_models, "n_groups": n_groups,
              "siblings": siblings, "shared_len": shared_len,
              "tail_len": tail_len, "max_new": max_new}
    out = {"params": params}
    for name, affinity in (("affinity", True), ("loadonly", False)):
        out[name] = _run_mode(affinity, n_models, n_groups, siblings,
                              shared_len, tail_len, max_new)
    out["tok_s_ratio"] = (out["affinity"]["tok_s"]
                          / max(out["loadonly"]["tok_s"], 1e-9))
    out["duplicate_kv_bytes_saved"] = (
        out["loadonly"]["duplicate_prefill_kv_bytes"]
        - out["affinity"]["duplicate_prefill_kv_bytes"])
    out["affinity_strictly_fewer"] = (
        out["affinity"]["duplicate_prefill_tokens"]
        < out["loadonly"]["duplicate_prefill_tokens"])
    return out


def main():
    res = bench_affinity()
    save("bench_affinity", res)
    emit("affinity_tok_s", res["affinity"]["wall_s"] * 1e6, res["affinity"])
    emit("loadonly_tok_s", res["loadonly"]["wall_s"] * 1e6, res["loadonly"])
    emit("affinity_dup_kv_bytes_saved", res["duplicate_kv_bytes_saved"],
         {"ratio": res["tok_s_ratio"]})
    return res


def quick():
    """Reduced sizes for the CI artifact + regression gate."""
    res = bench_affinity(n_models=2, n_groups=2, siblings=3,
                         shared_len=64, tail_len=8, max_new=4)
    save("bench_affinity_quick", res)
    emit("affinity_tok_s", res["affinity"]["wall_s"] * 1e6, res["affinity"])
    emit("loadonly_tok_s", res["loadonly"]["wall_s"] * 1e6, res["loadonly"])
    emit("affinity_dup_kv_bytes_saved", res["duplicate_kv_bytes_saved"],
         {"ratio": res["tok_s_ratio"]})
    return res


if __name__ == "__main__":
    quick() if "quick" in sys.argv[1:] else main()
