"""Cross-node KV page migration vs prefill-from-scratch (PR 5).

Shared-prompt workload over 2-3 model nodes on SimNet, each with its own
paged RealEngine.  Every group's prefix is seeded on one holder, then the
holder is made to look pressured in every peer's (stale) sync view — the
regime where PR-3 affinity routing is vetoed and the hottest prefixes
get re-prefilled from scratch exactly when the system is most loaded.
With ``replicate`` on, ``decide()`` routes the siblings to a peer with
headroom carrying a fetch hint: the peer pulls the prefix pages over the
overlay once (``kv_fetch``/``kv_pages``), later siblings piggyback on the
in-flight fetch or alias the landed replica, and admission prefills only
the divergence tails.

Reported per mode: generated tokens/s over the sibling phase (wall
clock), prefill tokens + dispatches, duplicate-prefill tokens (vs the
tail-only ideal), and the migration counters (fetches, imported pages,
wire bytes).  The token/dispatch/page counters are deterministic —
scripts/check_bench.py gates them against results/bench/baseline/ in CI.
"""
from __future__ import annotations

import sys
import time

from benchmarks.common import emit, save


def _build_nodes(n_models, cfg, model, params, replicate):
    from repro.core.forwarding import ForwardingConfig
    from repro.net.simnet import SimNet
    from repro.overlay.model_node import ModelNode
    from repro.serving.engine import RealEngine

    net = SimNet(seed=11)
    fwd = ForwardingConfig(replicate=replicate)
    nodes = [ModelNode(f"m{i}", use_crypto=False, fwd_cfg=fwd,
                       real_engine=RealEngine(cfg, model, params,
                                              max_len=256))
             for i in range(n_models)]
    for nd in nodes:
        net.add_node(nd.node_id, nd)
    members = [nd.node_id for nd in nodes]
    for nd in nodes:
        nd.join_group(members)
    return net, nodes


def _run_mode(replicate: bool, n_models: int, n_groups: int, siblings: int,
              shared_len: int, tail_len: int, max_new: int):
    import jax

    from repro.configs import base
    from repro.models.lm import build_model
    from repro.overlay.probe import ResponseSink, direct_payload

    assert shared_len % 32 == 0, "block-aligned shared prefix"
    cfg = base.get_config("gentorrent-llama3-8b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    net, nodes = _build_nodes(n_models, cfg, model, params, replicate)
    sink = ResponseSink()
    net.add_node("sink", sink)

    shared = {g: [(11 * (g + 1) + j) % cfg.vocab for j in range(shared_len)]
              for g in range(n_groups)}
    # seed phase: one request per group, pinned to its holder (also warms
    # every jit trace so the timed sibling phase is compile-free)
    for g in range(n_groups):
        holder = nodes[g % n_models]
        holder._process(net, direct_payload(f"seed{g}",
                                            shared[g] + [1] * tail_len,
                                            max_new), forwarded=True)
    net.run_until(net.t + 60)
    if replicate:
        # warm the export/import path too (first gather/scatter pays an
        # XLA compile, like every other jit trace warmed by the seeds):
        # one self-roundtrip per node under fake digests that no real
        # request can ever match — the replica entry just idles in cache
        depth = shared_len // 32
        for i, nd in enumerate(nodes):
            eng = nd.real_engine
            _, entry = eng.prefix_cache.peek(shared[i % n_groups])
            if entry is None:
                continue               # node holds no seed (n_groups < n)
            buf = eng.export_pages(entry.handle, depth=depth)
            eng.import_pages(buf, [bytes([255, i, d] * 6)[:16]
                                   for d in range(depth)])
    for nd in nodes:
        nd.broadcast_state(net)
    net.run_until(net.t + 5)
    # stale pressured view: every peer looks both loaded past the
    # affinity bound AND nearly out of arena — the double veto that used
    # to drop the sketch hit on the floor.  Each node trusts its own low
    # load, so it keeps the request AND (with replicate on) pulls the
    # pages it is missing.
    for nd in nodes:
        for pid, p in nd.peers.items():
            if pid != nd.node_id:
                p.active_requests = 6          # relative load 1.2
                p.kv_pressure = 0.95

    pre_tokens = {nd.node_id: nd.real_engine.prefill_tokens for nd in nodes}
    pre_disp = {nd.node_id: nd.real_engine.prefill_dispatches for nd in nodes}
    n_sib = 0
    for g in range(n_groups):
        entry = nodes[(g + 1) % n_models]
        for s in range(siblings):
            toks = shared[g] + [50 + 7 * s] * tail_len
            net.call_after(0.01, entry._process, net,
                           direct_payload(f"g{g}s{s}", toks, max_new))
            n_sib += 1
    t0 = time.perf_counter()
    net.run_until(net.t + 240)
    wall = time.perf_counter() - t0

    sib_outputs = [v for k, v in sink.got.items() if k.startswith("g")]
    gen_tokens = sum(len(o) for o in sib_outputs)
    prefill_tokens = sum(nd.real_engine.prefill_tokens
                         - pre_tokens[nd.node_id] for nd in nodes)
    dispatches = sum(nd.real_engine.prefill_dispatches
                     - pre_disp[nd.node_id] for nd in nodes)
    # ideal sibling prefill = divergence tail only (the block-aligned
    # shared prefix is cached somewhere in the group after its seed)
    ideal = n_sib * tail_len
    token_bytes = nodes[0].real_engine.page_bytes // 32

    def msum(key):
        return sum(nd.metrics[key] for nd in nodes)

    return {
        "completed": len(sib_outputs),
        "generated_tokens": gen_tokens,
        "wall_s": wall,
        "tok_s": gen_tokens / wall if wall > 0 else 0.0,
        "prefill_tokens": prefill_tokens,
        "prefill_dispatches": dispatches,
        "duplicate_prefill_tokens": prefill_tokens - ideal,
        "duplicate_prefill_kv_bytes": (prefill_tokens - ideal) * token_bytes,
        "replicate_routes": msum("replicate_routes"),
        "kv_fetches": msum("kv_fetches"),
        "kv_fetch_piggybacks": msum("kv_fetch_piggybacks"),
        "kv_imported_pages": msum("kv_imported_pages"),
        "kv_exports": msum("kv_exports"),
        "kv_fallbacks": msum("kv_fallbacks"),
        "kv_wire_bytes": msum("kv_wire_bytes"),
    }


def bench_migration(n_models: int = 3, n_groups: int = 3, siblings: int = 4,
                    shared_len: int = 96, tail_len: int = 8,
                    max_new: int = 8) -> dict:
    params = {"n_models": n_models, "n_groups": n_groups,
              "siblings": siblings, "shared_len": shared_len,
              "tail_len": tail_len, "max_new": max_new}
    out = {"params": params}
    for name, replicate in (("replicate", True), ("scratch", False)):
        out[name] = _run_mode(replicate, n_models, n_groups, siblings,
                              shared_len, tail_len, max_new)
    out["tok_s_ratio"] = (out["replicate"]["tok_s"]
                          / max(out["scratch"]["tok_s"], 1e-9))
    # the headline: duplicate prefill work the migration eliminated
    out["duplicate_dispatches_saved"] = (
        out["scratch"]["prefill_dispatches"]
        - out["replicate"]["prefill_dispatches"])
    out["duplicate_tokens_saved"] = (
        out["scratch"]["duplicate_prefill_tokens"]
        - out["replicate"]["duplicate_prefill_tokens"])
    out["replicate_zero_duplicates"] = (
        out["replicate"]["duplicate_prefill_tokens"] == 0)
    return out


def _emit(res: dict):
    emit("migration_replicate_tok_s", res["replicate"]["wall_s"] * 1e6,
         res["replicate"])
    emit("migration_scratch_tok_s", res["scratch"]["wall_s"] * 1e6,
         res["scratch"])
    emit("migration_dup_dispatches_saved",
         res["duplicate_dispatches_saved"],
         {"ratio": res["tok_s_ratio"],
          "wire_bytes": res["replicate"]["kv_wire_bytes"]})


def main():
    res = bench_migration()
    save("bench_migration", res)
    _emit(res)
    return res


def quick():
    """Reduced sizes for the CI artifact + regression gate."""
    res = bench_migration(n_models=2, n_groups=2, siblings=3,
                          shared_len=96, tail_len=8, max_new=4)
    save("bench_migration_quick", res)
    _emit(res)
    return res


if __name__ == "__main__":
    quick() if "quick" in sys.argv[1:] else main()
