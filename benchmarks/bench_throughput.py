"""Fig 18: normalized LLM throughput per workload (GenTorrent ToolUse = 1),
GenTorrent vs no-HR-tree — plus real-engine comparisons on the reduced
config: slot-pool batched decode vs the sequential per-request path
(tokens/s), and paged-vs-dense KV (live KV bytes at equal occupancy and
prefix-hit admission latency for a shared-prompt workload)."""
from __future__ import annotations

import sys
import time

from benchmarks.common import SCALE, emit, save
from benchmarks.serving_sim import run_serving_sim


def bench_continuous_batching(max_active: int = 4, n_req: int = 8,
                              max_new: int = 48, prompt_len: int = 16):
    """Decode throughput, sequential vs slot-pool batched, same requests.

    Distinct prompts (no cross-request prefix hits) so both paths do the
    same prefill + decode work; compile time excluded via warmup.  Decode-
    weighted (short prompts, long generation): admission prefill is the
    same batch-1 path for both, so the contrast isolates the per-round
    single-dispatch pool decode."""
    import jax

    from repro.configs import base
    from repro.models.lm import build_model
    from repro.serving.engine import RealEngine, Request
    from repro.serving.scheduler import Scheduler

    cfg = base.get_config("gentorrent-llama3-8b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = [[(37 * i + j) % cfg.vocab for j in range(prompt_len)]
               for i in range(n_req)]
    warm = [[(501 + j) % cfg.vocab for j in range(prompt_len)]]

    eng_s = RealEngine(cfg, model, params, max_len=256)
    eng_s.generate(Request(0, warm[0], max_new=2))          # compile
    t0 = time.perf_counter()
    seq_toks = sum(len(eng_s.generate(
        Request(1 + i, p, max_new=max_new)).output)
        for i, p in enumerate(prompts))
    seq_s = time.perf_counter() - t0

    eng_b = RealEngine(cfg, model, params, max_len=256)
    sched = Scheduler(eng_b, max_active=max_active)
    sched.submit(Request(0, warm[0], max_new=2))            # compile
    sched.run()
    sched.done.clear()
    calls0 = sched.metrics["decode_calls"]                  # exclude warmup
    for i, p in enumerate(prompts):
        sched.submit(Request(1 + i, p, max_new=max_new))
    t0 = time.perf_counter()
    done = sched.run()
    bat_s = time.perf_counter() - t0
    bat_toks = sum(len(r.output) for r in done)
    calls = sched.metrics["decode_calls"] - calls0

    return {"max_active": max_active, "n_req": n_req, "max_new": max_new,
            "sequential_tok_s": seq_toks / seq_s,
            "batched_tok_s": bat_toks / bat_s,
            "speedup": (bat_toks / bat_s) / (seq_toks / seq_s),
            "decode_calls": calls,
            "us_per_decode_round": bat_s * 1e6 / max(1, calls),
            "batched_traces": eng_b.batched_traces}


def bench_paged_kv(max_active: int = 4, shared_len: int = 96,
                   tail_len: int = 8, max_new: int = 16):
    """Paged vs dense KV at equal occupancy, shared-prompt workload.

    All requests share a ``shared_len``-token prompt prefix.  Reported per
    mode: (a) live KV bytes once ``max_active`` requests are admitted —
    the dense pool pins ``max_active x max_len`` strips plus a full cache
    *copy* per prefix-cache entry, while the paged pool holds one physical
    copy of the shared pages (aliased by every slot) plus per-request tail
    pages; (b) prefix-hit admission latency — dense replays the suffix
    token-by-token over a max_len cache, paged aliases the cached pages
    (refcount bump) and chunk-prefills only the divergence suffix."""
    import jax

    from repro.configs import base
    from repro.models.lm import build_model
    from repro.serving.engine import RealEngine, Request
    from repro.serving.scheduler import Scheduler

    cfg = base.get_config("gentorrent-llama3-8b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    shared = [(11 + j) % cfg.vocab for j in range(shared_len)]

    out = {"max_active": max_active, "shared_len": shared_len,
           "tail_len": tail_len}
    for mode, paged in (("dense", False), ("paged", True)):
        eng = RealEngine(cfg, model, params, max_len=256, paged=paged)
        # warm: compile + seed the prefix cache with the shared prompt
        eng.generate(Request(0, shared + [1] * tail_len, max_new=2))
        # admission latency on a prefix hit (compile already warm)
        t0 = time.perf_counter()
        st = eng.prefill_request(Request(1, shared + [2] * tail_len))
        admit_s = time.perf_counter() - t0
        if paged:
            eng.release_pages(st.pages)
        # equal occupancy: admit max_active hit requests, one step
        sched = Scheduler(eng, max_active=max_active)
        for i in range(max_active):
            sched.submit(Request(10 + i, shared + [3 + i] * tail_len,
                                 max_new=max_new))
        sched.step()
        out[mode] = {
            "kv_pool_bytes": sched.kv_bytes_in_use(),
            "prefix_cache_bytes": eng.prefix_cache.used_bytes,
            "admission_ms_on_hit": admit_s * 1e3,
        }
        sched.run()
    dense_total = (out["dense"]["kv_pool_bytes"]
                   + out["dense"]["prefix_cache_bytes"])
    paged_total = out["paged"]["kv_pool_bytes"]   # live pages include the
    out["bytes_ratio_paged_over_dense"] = paged_total / dense_total  # cache
    out["admission_speedup"] = (out["dense"]["admission_ms_on_hit"]
                                / out["paged"]["admission_ms_on_hit"])
    out["paged_strictly_lower"] = paged_total < dense_total
    return out


def main():
    n_req = max(400, int(900 * SCALE))
    raw = {}
    t0 = time.perf_counter()
    # sustained saturation (arrivals outlast the window; 64 engine slots at
    # ~2.5 s/request cap ~25 req/s) + fixed window: cache hits free prefill
    # slot time, so more requests complete inside the window (the paper's
    # "hit rate translates directly into throughput" regime).  Gains are
    # bounded by the decode share of service time in this cost model —
    # see EXPERIMENTS.md §Repro notes.
    for wl in ("ToolUse", "Coding", "LongQA", "Mixed"):
        raw[wl] = {
            "gentorrent": run_serving_sim(wl, "full", 45.0, n_req, seed=4,
                                          window_s=20.0)["throughput_tok_s"],
            "no_hrtree": run_serving_sim(wl, "none", 45.0, n_req, seed=4,
                                         window_s=20.0)["throughput_tok_s"],
        }
    base = raw["ToolUse"]["gentorrent"] or 1e-9
    rows = {wl: {k: v / base for k, v in d.items()}
            for wl, d in raw.items()}
    us = (time.perf_counter() - t0) * 1e6 / (len(raw) * 2)
    cb = bench_continuous_batching()
    pk = bench_paged_kv()
    save("fig18_throughput", {"normalized": rows, "raw_tok_s": raw,
                              "continuous_batching": cb,
                              "paged_kv": pk})
    emit("fig18_normalized_throughput", us, rows)
    emit("continuous_batching_tok_s", cb["us_per_decode_round"], cb)
    emit("paged_kv_admission_us",
         pk["paged"]["admission_ms_on_hit"] * 1e3, pk)
    return rows


def quick():
    """Engine-only benches at reduced sizes (CI artifact: keeps the perf
    trajectory visible per PR without the overlay-scale sim)."""
    cb = bench_continuous_batching(n_req=4, max_new=16)
    pk = bench_paged_kv(max_active=4, shared_len=64, max_new=8)
    save("fig18_throughput_quick", {"continuous_batching": cb,
                                    "paged_kv": pk})
    emit("continuous_batching_tok_s", cb["us_per_decode_round"], cb)
    emit("paged_kv_admission_us",
         pk["paged"]["admission_ms_on_hit"] * 1e3, pk)


if __name__ == "__main__":
    quick() if "quick" in sys.argv[1:] else main()
