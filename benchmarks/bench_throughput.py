"""Fig 18: normalized LLM throughput per workload (GenTorrent ToolUse = 1),
GenTorrent vs no-HR-tree — plus a real-engine continuous-batching
comparison: slot-pool batched decode (one dispatch per round) vs the
sequential per-request path, tokens/s on the reduced config."""
from __future__ import annotations

import time

from benchmarks.common import SCALE, emit, save
from benchmarks.serving_sim import run_serving_sim


def bench_continuous_batching(max_active: int = 4, n_req: int = 8,
                              max_new: int = 48, prompt_len: int = 16):
    """Decode throughput, sequential vs slot-pool batched, same requests.

    Distinct prompts (no cross-request prefix hits) so both paths do the
    same prefill + decode work; compile time excluded via warmup.  Decode-
    weighted (short prompts, long generation): admission prefill is the
    same batch-1 path for both, so the contrast isolates the per-round
    single-dispatch pool decode."""
    import jax

    from repro.configs import base
    from repro.models.lm import build_model
    from repro.serving.engine import RealEngine, Request
    from repro.serving.scheduler import Scheduler

    cfg = base.get_config("gentorrent-llama3-8b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = [[(37 * i + j) % cfg.vocab for j in range(prompt_len)]
               for i in range(n_req)]
    warm = [[(501 + j) % cfg.vocab for j in range(prompt_len)]]

    eng_s = RealEngine(cfg, model, params, max_len=256)
    eng_s.generate(Request(0, warm[0], max_new=2))          # compile
    t0 = time.perf_counter()
    seq_toks = sum(len(eng_s.generate(
        Request(1 + i, p, max_new=max_new)).output)
        for i, p in enumerate(prompts))
    seq_s = time.perf_counter() - t0

    eng_b = RealEngine(cfg, model, params, max_len=256)
    sched = Scheduler(eng_b, max_active=max_active)
    sched.submit(Request(0, warm[0], max_new=2))            # compile
    sched.run()
    sched.done.clear()
    calls0 = sched.metrics["decode_calls"]                  # exclude warmup
    for i, p in enumerate(prompts):
        sched.submit(Request(1 + i, p, max_new=max_new))
    t0 = time.perf_counter()
    done = sched.run()
    bat_s = time.perf_counter() - t0
    bat_toks = sum(len(r.output) for r in done)
    calls = sched.metrics["decode_calls"] - calls0

    return {"max_active": max_active, "n_req": n_req, "max_new": max_new,
            "sequential_tok_s": seq_toks / seq_s,
            "batched_tok_s": bat_toks / bat_s,
            "speedup": (bat_toks / bat_s) / (seq_toks / seq_s),
            "decode_calls": calls,
            "us_per_decode_round": bat_s * 1e6 / max(1, calls),
            "batched_traces": eng_b.batched_traces}


def main():
    n_req = max(400, int(900 * SCALE))
    raw = {}
    t0 = time.perf_counter()
    # sustained saturation (arrivals outlast the window; 64 engine slots at
    # ~2.5 s/request cap ~25 req/s) + fixed window: cache hits free prefill
    # slot time, so more requests complete inside the window (the paper's
    # "hit rate translates directly into throughput" regime).  Gains are
    # bounded by the decode share of service time in this cost model —
    # see EXPERIMENTS.md §Repro notes.
    for wl in ("ToolUse", "Coding", "LongQA", "Mixed"):
        raw[wl] = {
            "gentorrent": run_serving_sim(wl, "full", 45.0, n_req, seed=4,
                                          window_s=20.0)["throughput_tok_s"],
            "no_hrtree": run_serving_sim(wl, "none", 45.0, n_req, seed=4,
                                         window_s=20.0)["throughput_tok_s"],
        }
    base = raw["ToolUse"]["gentorrent"] or 1e-9
    rows = {wl: {k: v / base for k, v in d.items()}
            for wl, d in raw.items()}
    us = (time.perf_counter() - t0) * 1e6 / (len(raw) * 2)
    cb = bench_continuous_batching()
    save("fig18_throughput", {"normalized": rows, "raw_tok_s": raw,
                              "continuous_batching": cb})
    emit("fig18_normalized_throughput", us, rows)
    emit("continuous_batching_tok_s", cb["us_per_decode_round"], cb)
    return rows


if __name__ == "__main__":
    main()
