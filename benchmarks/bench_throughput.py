"""Fig 18: normalized LLM throughput per workload (GenTorrent ToolUse = 1),
GenTorrent vs no-HR-tree."""
from __future__ import annotations

import time

from benchmarks.common import SCALE, emit, save
from benchmarks.serving_sim import run_serving_sim


def main():
    n_req = max(400, int(900 * SCALE))
    raw = {}
    t0 = time.perf_counter()
    # sustained saturation (arrivals outlast the window; 64 engine slots at
    # ~2.5 s/request cap ~25 req/s) + fixed window: cache hits free prefill
    # slot time, so more requests complete inside the window (the paper's
    # "hit rate translates directly into throughput" regime).  Gains are
    # bounded by the decode share of service time in this cost model —
    # see EXPERIMENTS.md §Repro notes.
    for wl in ("ToolUse", "Coding", "LongQA", "Mixed"):
        raw[wl] = {
            "gentorrent": run_serving_sim(wl, "full", 45.0, n_req, seed=4,
                                          window_s=20.0)["throughput_tok_s"],
            "no_hrtree": run_serving_sim(wl, "none", 45.0, n_req, seed=4,
                                         window_s=20.0)["throughput_tok_s"],
        }
    base = raw["ToolUse"]["gentorrent"] or 1e-9
    rows = {wl: {k: v / base for k, v in d.items()}
            for wl, d in raw.items()}
    us = (time.perf_counter() - t0) * 1e6 / (len(raw) * 2)
    save("fig18_throughput", {"normalized": rows, "raw_tok_s": raw})
    emit("fig18_normalized_throughput", us, rows)
    return rows


if __name__ == "__main__":
    main()
