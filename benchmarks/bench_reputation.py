"""Fig 12: reputation trajectories over 35 epochs under punishment levels
gamma in {1, 1/3, 1/5} for GT + four degraded models."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import SCALE, emit, save
from benchmarks.gt_model import greedy, impostors, trained_gt
from repro.core.reputation import ReputationConfig, ReputationTracker
from repro.core.verification import VerifierModel, credibility


def main():
    cfg, model, params, corpus = trained_gt()
    verifier = VerifierModel(cfg, model, params)
    models = {"GT": params, **impostors(params)}
    epochs = 35
    challenges_per_epoch = max(1, int(2 * SCALE))
    rng = np.random.default_rng(1)

    # precompute per-epoch C(T) for each model
    t0 = time.perf_counter()
    c_series = {k: [] for k in models}
    for e in range(epochs):
        prompts = [corpus.sample(1, 16, rng)[0, :16].tolist()
                   for _ in range(challenges_per_epoch)]
        for name, p in models.items():
            vals = [credibility(verifier, pr, greedy(model, p, pr, n=12))
                    for pr in prompts]
            c_series[name].append(float(np.mean(vals)))
    gammas = {"level1_gamma=1": 1.0, "level2_gamma=1/3": 1 / 3,
              "level3_gamma=1/5": 1 / 5}
    # tau_abnormal rescaled to this GT model's score regime (GT ~0.55);
    # the paper likewise picked its threshold empirically for its stack
    out = {}
    for gname, gamma in gammas.items():
        trackers = {k: ReputationTracker(
            ReputationConfig(gamma=gamma, tau_abnormal=0.47))
                    for k in models}
        traj = {k: [] for k in models}
        for e in range(epochs):
            for k in models:
                traj[k].append(round(trackers[k].update(k, c_series[k][e]), 4))
        out[gname] = traj
    us = (time.perf_counter() - t0) * 1e6 / (epochs * len(models))
    finals = {g: {k: v[-1] for k, v in t.items()} for g, t in out.items()}
    save("fig12_reputation", {"trajectories": out, "c_series": c_series,
                              "finals": finals})
    emit("fig12_reputation_epoch", us, finals)
    # paper finding: gamma=1/5 detects dishonest models fastest (< 0.4)
    worst = min(out["level3_gamma=1/5"][m][-1]
                for m in ("m2", "m3"))
    assert worst < 0.4, "harsh impostors must end untrusted at gamma=1/5"
    return out


if __name__ == "__main__":
    main()
