"""Fig 11: normalized-perplexity credit scores of GT vs degraded models
over a batch of challenge prompts."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import SCALE, emit, save
from benchmarks.gt_model import greedy, impostors, trained_gt
from repro.core.verification import VerifierModel, credibility


def main():
    cfg, model, params, corpus = trained_gt()
    verifier = VerifierModel(cfg, model, params)
    models = {"GT": params, **impostors(params)}
    n_prompts = max(6, int(30 * SCALE))
    rng = np.random.default_rng(0)
    scores = {k: [] for k in models}
    t0 = time.perf_counter()
    for i in range(n_prompts):
        prompt = corpus.sample(1, 16, rng)[0, :16].tolist()
        for name, p in models.items():
            resp = greedy(model, p, prompt, n=16)
            scores[name].append(credibility(verifier, prompt, resp))
    us = (time.perf_counter() - t0) * 1e6 / (n_prompts * len(models))
    stats = {k: {"mean": float(np.mean(v)), "std": float(np.std(v))}
             for k, v in scores.items()}
    save("fig11_credit_scores", {"n_prompts": n_prompts, "stats": stats,
                                 "scores": scores})
    emit("fig11_credit_per_challenge", us, stats)
    assert stats["GT"]["mean"] >= max(
        stats[m]["mean"] for m in ("m1", "m2", "m3", "m4")), \
        "GT must score highest (paper Fig 11)"
    return stats


if __name__ == "__main__":
    main()
