"""Benchmark harness: one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines; JSON artifacts land in
results/bench/.  BENCH_SCALE=0.2 shrinks trial counts for smoke runs.
"""
from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from benchmarks import (bench_anonymity, bench_cache_hit,
                            bench_churn, bench_clove_latency,
                            bench_confidentiality, bench_credit,
                            bench_kernels, bench_reputation,
                            bench_roofline, bench_serving_latency,
                            bench_throughput, bench_verification)
    suites = [
        ("fig9_anonymity", bench_anonymity.main),
        ("fig10_confidentiality", bench_confidentiality.main),
        ("fig11_credit", bench_credit.main),
        ("fig12_reputation", bench_reputation.main),
        ("fig13_clove_latency", bench_clove_latency.main),
        ("fig14_churn", bench_churn.main),
        ("fig15_16_serving_latency", bench_serving_latency.main),
        ("fig17_cache_hit", bench_cache_hit.main),
        ("fig18_throughput", bench_throughput.main),
        ("sec5.4_verification", bench_verification.main),
        ("kernels", bench_kernels.main),
        ("roofline", bench_roofline.main),
    ]
    failures = []
    for name, fn in suites:
        t0 = time.time()
        try:
            fn()
            print(f"# {name}: done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            failures.append(name)
            print(f"# {name}: FAILED\n{traceback.format_exc()}",
                  file=sys.stderr, flush=True)
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
