"""Benchmark harness: one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines; JSON artifacts land in
results/bench/.  BENCH_SCALE=0.2 shrinks trial counts for smoke runs.
``python -m benchmarks.run quick`` runs each suite's reduced ``quick``
mode instead (the CI artifact path); suites without one are skipped
cleanly rather than crashing the run.
"""
from __future__ import annotations

import sys
import time
import traceback


def main(quick: bool = False) -> None:
    from benchmarks import (bench_affinity, bench_anonymity, bench_cache_hit,
                            bench_churn, bench_clove_latency,
                            bench_confidentiality, bench_credit,
                            bench_kernels, bench_migration,
                            bench_reputation, bench_roofline,
                            bench_serving_latency, bench_spec,
                            bench_throughput, bench_verification)
    suites = [
        ("fig9_anonymity", bench_anonymity),
        ("fig10_confidentiality", bench_confidentiality),
        ("fig11_credit", bench_credit),
        ("fig12_reputation", bench_reputation),
        ("fig13_clove_latency", bench_clove_latency),
        ("fig14_churn", bench_churn),
        ("fig15_16_serving_latency", bench_serving_latency),
        ("fig17_cache_hit", bench_cache_hit),
        ("fig18_throughput", bench_throughput),
        ("sec5.4_verification", bench_verification),
        ("kernels", bench_kernels),
        ("roofline", bench_roofline),
        ("affinity_routing", bench_affinity),
        ("spec_decode", bench_spec),
        ("kv_migration", bench_migration),
    ]
    failures = []
    for name, mod in suites:
        fn = getattr(mod, "quick", None) if quick else getattr(mod, "main")
        if fn is None:
            print(f"# {name}: skipped (no quick mode)", flush=True)
            continue
        t0 = time.time()
        try:
            fn()
            print(f"# {name}: done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            failures.append(name)
            print(f"# {name}: FAILED\n{traceback.format_exc()}",
                  file=sys.stderr, flush=True)
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main(quick="quick" in sys.argv[1:])
