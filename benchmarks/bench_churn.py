"""Fig 14: communication survival under churn (200 nodes/min, 3119-node
network, no proxy re-discovery) for GenTorrent / garlic-cast / onion.

Churn model (calibration documented in EXPERIMENTS.md): 200 churn events
per minute = ~100 leaves + ~100 (re)joins; 10% of leaves are permanent
departures.  GenTorrent/GC paths tolerate *temporary* relay absence (the
relay resumes with its stored {path_id: pred/succ} state, and k-of-n
delivery rides out short gaps); they die only on permanent departures.
Onion circuits break on ANY relay leave (no redundancy, no self-heal) —
the structural gap Fig 14 measures.  GC uses longer random-walk paths
(5 hops vs 3), increasing its exposure.
"""
from __future__ import annotations

import random
import time

from benchmarks.common import SCALE, emit, save


def _leave_times(N, leave_rate_per_min, perm_frac, minutes, rng):
    """Per-node: (first permanent-leave time, list of any-leave times)."""
    perm = {}
    any_leave = {}
    lam = leave_rate_per_min / N     # per-node leaves per minute
    for node in range(N):
        t = 0.0
        while True:
            t += rng.expovariate(lam)
            if t > minutes:
                break
            any_leave.setdefault(node, t)
            if rng.random() < perm_frac:
                perm[node] = t
                break
    return perm, any_leave


def survival_curves(N, churn_per_min, minutes, trials, rng):
    leave_rate = churn_per_min / 2.0      # events = leaves + joins
    perm_frac = 0.10
    mins = list(range(minutes + 1))
    acc = {"gentorrent": [0.0] * len(mins), "garlic_cast": [0.0] * len(mins),
           "onion": [0.0] * len(mins)}
    for _ in range(trials):
        perm, any_leave = _leave_times(N, leave_rate, perm_frac, minutes,
                                       rng)
        nodes = list(range(N))
        rng.shuffle(nodes)
        gt_paths = [nodes[i * 3:(i + 1) * 3] for i in range(4)]
        gc_paths = [nodes[12 + i * 5:12 + (i + 1) * 5] for i in range(4)]
        onion_path = nodes[32:35]
        for i, t in enumerate(mins):
            gt_alive = sum(1 for p in gt_paths
                           if all(perm.get(r, 1e9) > t for r in p))
            acc["gentorrent"][i] += 1.0 if gt_alive >= 3 else 0.0
            gc_alive = sum(1 for p in gc_paths
                           if all(perm.get(r, 1e9) > t for r in p))
            acc["garlic_cast"][i] += 1.0 if gc_alive >= 3 else 0.0
            ok = all(any_leave.get(r, 1e9) > t for r in onion_path)
            acc["onion"][i] += 1.0 if ok else 0.0
    return {k: [v / trials for v in vs] for k, vs in acc.items()}


def main():
    N = 3119
    churn = 200
    minutes = 15
    trials = max(200, int(1500 * SCALE))
    rng = random.Random(0)
    t0 = time.perf_counter()
    curves = survival_curves(N, churn, minutes, trials, rng)
    us = (time.perf_counter() - t0) * 1e6 / trials
    save("fig14_churn_survival",
         {"N": N, "churn_per_min": churn, "trials": trials,
          "minutes": list(range(minutes + 1)), **curves})
    emit("fig14_survival_trial", us,
         {"gentorrent_15min": curves["gentorrent"][-1],
          "garlic_cast_15min": curves["garlic_cast"][-1],
          "onion_15min": curves["onion"][-1],
          "paper_gentorrent_15min": ">0.80"})
    assert curves["gentorrent"][-1] > 0.7
    assert curves["gentorrent"][-1] > curves["onion"][-1]
    return curves


if __name__ == "__main__":
    main()
