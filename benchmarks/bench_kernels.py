"""Kernel micro-benchmarks: jnp reference path wall time on CPU (the
Pallas kernels themselves are TPU-targeted; interpret mode is a
correctness tool, not a perf number) + HR-tree ops throughput."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core import hrtree
from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.flash_attention.ops import flash_attention
from repro.serving.prefix_cache import PrefixCache


def main():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (1, 8, 512, 64), jnp.float32)
    kv = jax.random.normal(k2, (1, 4, 512, 64), jnp.float32)
    us, _ = timeit(lambda: jax.block_until_ready(
        flash_attention(q, kv, kv, impl="ref")))
    emit("flash_attention_ref_512", us, {"shape": "B1 H8 S512 D64"})

    qd = jax.random.normal(k3, (4, 8, 64), jnp.float32)
    kvd = jax.random.normal(k2, (4, 4, 2048, 64), jnp.float32)
    lengths = jnp.full((4,), 2048, jnp.int32)
    us, _ = timeit(lambda: jax.block_until_ready(
        decode_attention(qd, kvd, kvd, lengths, impl="ref")))
    emit("decode_attention_ref_2k", us, {"shape": "B4 H8 S2048 D64"})

    # HR-tree: preprocess + search throughput on 8k-token prompts
    t = hrtree.HRTree([64], bits=8, default_chunk=64)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 50_000, 8192).tolist() for _ in range(16)]
    for p in prompts:
        t.insert_tokens(p, "self")
    t0 = time.perf_counter()
    for p in prompts * 4:
        t.search_tokens(p, tau=2)
    us = (time.perf_counter() - t0) / (len(prompts) * 4) * 1e6
    emit("hrtree_search_8k_tokens", us, {"tree_nodes": t.size()})

    pc = PrefixCache()
    for p in prompts:
        pc.insert(p, None, 1000)
    t0 = time.perf_counter()
    for p in prompts * 4:
        pc.match(p)
    us = (time.perf_counter() - t0) / (len(prompts) * 4) * 1e6
    emit("prefix_cache_match_8k", us, {"hit_rate": pc.hit_rate})


if __name__ == "__main__":
    main()
