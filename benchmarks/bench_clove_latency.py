"""Fig 13: CDF of clove preparation (model-node side) and decryption
(user-node side) latency.  Message sizes drawn from the ToolUse workload
(the paper's setup)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import SCALE, emit, save
from repro.core import sida
from repro.training.data import TOOLUSE, WorkloadGen


def main():
    trials = max(200, int(2_000 * SCALE))
    g = WorkloadGen(TOOLUSE, seed=0, scale=0.25)
    sizes = [len(g.sample().tokens) * 2 for _ in range(64)]  # ~bytes
    prep, dec = [], []
    for i in range(trials):
        msg = bytes(np.random.default_rng(i).integers(
            0, 256, sizes[i % len(sizes)], dtype=np.uint8))
        t0 = time.perf_counter()
        cloves = sida.make_cloves(msg, 4, 3)
        prep.append((time.perf_counter() - t0) * 1e3)
        t0 = time.perf_counter()
        out = sida.recover(cloves[:3])
        dec.append((time.perf_counter() - t0) * 1e3)
        assert out == msg
    stats = {
        "prepare_ms": {"mean": float(np.mean(prep)),
                       "p50": float(np.percentile(prep, 50)),
                       "p99": float(np.percentile(prep, 99))},
        "decrypt_ms": {"mean": float(np.mean(dec)),
                       "p50": float(np.percentile(dec, 50)),
                       "p99": float(np.percentile(dec, 99))},
        "success_rate": 1.0,
        "paper": {"prepare_ms_mean": 0.273, "decrypt_ms_mean": 0.302},
    }
    save("fig13_clove_latency", {"trials": trials, **stats})
    emit("fig13_clove_prepare", float(np.mean(prep)) * 1e3, stats)
    return stats


if __name__ == "__main__":
    main()
