"""Roofline table from the dry-run artifacts (results/dryrun): the three
terms per (arch x shape) on the single-pod mesh, dominant bottleneck, and
MODEL_FLOPS/HLO_FLOPS utilization ratio."""
from __future__ import annotations

import glob
import json
from pathlib import Path

from benchmarks.common import emit, save
from repro.configs import base as cfgbase
from repro.distributed.collectives import roofline_terms


def model_flops(rec: dict) -> float:
    """6*N*D for train (N=active params, D=tokens); 2*N*D for inference."""
    n_active = rec["params_active"]
    shape = cfgbase.SHAPES[rec["shape"]]
    if rec["kind"] == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if rec["kind"] == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: 1 token/request


def build_table(dryrun_dir="results/dryrun/pod16x16") -> list:
    rows = []
    for f in sorted(glob.glob(f"{dryrun_dir}/*.json")):
        r = json.loads(Path(f).read_text())
        if r["status"] != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "status": r["status"],
                         "reason": r.get("reason", r.get("error", ""))[:90]})
            continue
        h = r["hlo_analysis"]
        n_dev = r["n_devices"]
        t = roofline_terms(h["flops"], h["bytes"], h["coll_eff_bytes"])
        mf = model_flops(r)
        util = mf / (h["flops"] * n_dev) if h["flops"] else 0.0
        mem = r.get("memory_analysis", {})
        per_dev_hbm = (mem.get("argument_size_in_bytes", 0)
                       + mem.get("temp_size_in_bytes", 0)
                       - mem.get("alias_size_in_bytes", 0))
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "status": "ok",
            "t_compute_s": t["t_compute_s"], "t_memory_s": t["t_memory_s"],
            "t_collective_s": t["t_collective_s"],
            "dominant": t["dominant"],
            "model_flops": mf, "hlo_flops_per_dev": h["flops"],
            "useful_flops_ratio": util,
            "hbm_per_dev_gb": per_dev_hbm / 1e9,
            "fits_16gb": per_dev_hbm < 16e9,
            "compile_s": r.get("compile_s"),
        })
    return rows


def main():
    rows = build_table()
    save("roofline_table", {"rows": rows})
    ok = [r for r in rows if r["status"] == "ok"]
    worst = sorted(ok, key=lambda r: r["useful_flops_ratio"])[:3]
    coll = sorted(ok, key=lambda r: -r["t_collective_s"])[:3]
    emit("roofline_cells_ok", 0.0,
         {"n_ok": len(ok), "n_skipped": len(rows) - len(ok),
          "worst_useful_ratio": [
              (r["arch"], r["shape"], round(r["useful_flops_ratio"], 3))
              for r in worst],
          "most_collective_bound": [
              (r["arch"], r["shape"], round(r["t_collective_s"], 2))
              for r in coll]})
    return rows


if __name__ == "__main__":
    main()
