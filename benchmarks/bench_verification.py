"""§5.4: verification throughput (verifications per minute per node).

The paper needs 208 verifications/VN/hour (~3.5/min); its GH200 does 45/min
and A100 20.7/min.  Here the verifier model is the tiny CPU GT model, so we
report measured verifications/min on this host plus the model-size scaling
ratio needed to compare."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import SCALE, emit, save
from benchmarks.gt_model import greedy, trained_gt
from repro.core.verification import VerifierModel, credibility


def main():
    cfg, model, params, corpus = trained_gt()
    verifier = VerifierModel(cfg, model, params)
    rng = np.random.default_rng(5)
    n = max(10, int(50 * SCALE))
    pairs = []
    for _ in range(n):
        prompt = corpus.sample(1, 16, rng)[0, :16].tolist()
        pairs.append((prompt, greedy(model, params, prompt, n=16)))
    t0 = time.perf_counter()
    for p, r in pairs:
        credibility(verifier, p, r)
    dt = time.perf_counter() - t0
    per_min = n / dt * 60
    out = {"verifications_per_min": per_min,
           "model": f"reduced {cfg.name} ({cfg.d_model}d/{cfg.n_layers}L)",
           "paper_gh200_per_min": 45.04, "paper_a100_per_min": 20.72,
           "required_per_hour": 208}
    save("tab_verification_throughput", out)
    emit("verification_throughput", dt / n * 1e6, out)
    assert per_min * 60 > 208, "must exceed the paper's required rate"
    return out


if __name__ == "__main__":
    main()
