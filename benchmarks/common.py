"""Shared benchmark utilities: timing, CSV emission, result persistence."""
from __future__ import annotations

import json
import os
import time
from pathlib import Path

RESULTS = Path(os.environ.get("BENCH_OUT", "results/bench"))
SCALE = float(os.environ.get("BENCH_SCALE", "1.0"))  # <1 = faster smoke


def emit(name: str, us_per_call: float, derived: dict | None = None):
    """The harness contract: ``name,us_per_call,derived`` CSV lines."""
    print(f"{name},{us_per_call:.3f},{json.dumps(derived or {}, default=str)}")


def save(name: str, payload: dict):
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / f"{name}.json").write_text(
        json.dumps(payload, indent=1, default=str))


def timeit(fn, *args, repeats=5, warmup=1, **kw):
    for _ in range(warmup):
        fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeats
    return dt * 1e6, out  # us
