"""Shared overlay-serving simulation driver for Figs 15-18.

Runs a GenTorrent overlay (simnet) with 8 model nodes — two hardware
tiers like the paper's testbed (A6000-class hw=4 / A100-class hw=8) —
against a workload at a given Poisson request rate, in one of three modes:

  full     HR-tree forwarding + load balancing  (GenTorrent)
  lb_only  load balancing only                  (Fig 16 middle bar)
  none     no overlay forwarding                (w/o HR-tree baseline)

Returns Avg/P99 latency, TTFT, cache hit rates, and throughput.
"""
from __future__ import annotations

import numpy as np

from repro.overlay.network import OverlayConfig, build_overlay
from repro.training.data import (CODING, LONGQA, TOOLUSE, MixedWorkload,
                                 WorkloadGen, poisson_arrivals)

WORKLOADS = {
    "ToolUse": lambda seed: WorkloadGen(TOOLUSE, seed=seed),
    "Coding": lambda seed: WorkloadGen(CODING, seed=seed),
    "LongQA": lambda seed: WorkloadGen(LONGQA, seed=seed),
    "Mixed": lambda seed: MixedWorkload(seed=seed),
}


def run_serving_sim(workload: str, mode: str, rate: float,
                    n_requests: int = 120, seed: int = 0,
                    n_users: int = 24, n_models: int = 8,
                    window_s: float = 0.0) -> dict:
    """window_s > 0: measure completions within a FIXED window after the
    first arrival (saturated-throughput regime, Fig 18); otherwise run to
    completion (latency regime, Figs 15-17)."""
    ov = build_overlay(OverlayConfig(
        n_users=n_users, n_models=n_models, use_crypto=False, seed=seed,
        sync_every=5.0,
        # per-node cache holds ~8 ToolUse-sized prefixes: the group's
        # aggregate capacity (8 nodes) covers the working set only when
        # HR-tree affinity routing specializes the nodes (paper §3.3)
        cache_bytes=64 << 20,
        hw_scores=[4, 4, 4, 4, 8, 8, 8, 8]))  # two hardware tiers (§5.1)
    for m in ov.models:
        m.fwd_mode = mode
    gen = WORKLOADS[workload](seed + 1)
    arrivals = poisson_arrivals(rate, n_requests, seed=seed + 2,
                                t0=ov.net.t + 1.0)
    done = []

    def cb(_net, payload):
        done.append(payload)

    rng = np.random.default_rng(seed + 3)
    for t, _ in zip(arrivals, range(n_requests)):
        q = gen.sample()
        uid = int(rng.integers(0, n_users))
        u = ov.users[uid]
        u.on_response = cb

        def fire(u=u, q=q):
            u.send_prompt(ov.net, q.tokens,
                          session=f"s{q.prefix_id}",
                          extra_meta={"max_new": q.max_new})

        ov.net.call_at(t, fire)
    if window_s > 0:
        ov.net.run_until(arrivals[0] + window_s)
    else:
        ov.net.run_until(arrivals[-1] + 600)

    ttfts, totals, served, hits = [], [], 0, 0
    cached_t, prompt_t = 0, 0
    for m in ov.models:
        ttfts += m.metrics["ttft"]
        totals += m.metrics["total"]
        served += m.metrics["served"]
        hits += m.metrics["cache_hits"]
        cached_t += m.metrics["cached_tokens"]
        prompt_t += m.metrics["prompt_tokens"]
    out_tokens = sum(len(p.get("output", [])) for p in done)
    span = (window_s if window_s > 0 else ov.net.t - arrivals[0])
    return {
        "workload": workload, "mode": mode, "rate": rate,
        "completed": len(done), "served": served,
        "avg_latency_s": float(np.mean(totals)) if totals else None,
        "p99_latency_s": float(np.percentile(totals, 99)) if totals else None,
        "ttft_s": float(np.mean(ttfts)) if ttfts else None,
        "cache_hit_decisions": hits,
        "token_hit_rate": cached_t / prompt_t if prompt_t else 0.0,
        "throughput_tok_s": out_tokens / span if span > 0 else 0.0,
    }
