"""Fig 17: KV-cache hit rates across workloads, GenTorrent vs no-HR-tree."""
from __future__ import annotations

import time

from benchmarks.common import SCALE, emit, save
from benchmarks.serving_sim import run_serving_sim


def main():
    n_req = max(40, int(120 * SCALE))
    rows = {}
    t0 = time.perf_counter()
    for wl in ("ToolUse", "Coding", "LongQA", "Mixed"):
        w = run_serving_sim(wl, "full", 2.0, n_req, seed=3)
        wo = run_serving_sim(wl, "none", 2.0, n_req, seed=3)
        rows[wl] = {"gentorrent": w["token_hit_rate"],
                    "no_hrtree": wo["token_hit_rate"]}
    us = (time.perf_counter() - t0) * 1e6 / (len(rows) * 2)
    save("fig17_cache_hit", rows)
    emit("fig17_cache_hit_rates", us, rows)
    assert rows["ToolUse"]["gentorrent"] >= rows["ToolUse"]["no_hrtree"]
    return rows


if __name__ == "__main__":
    main()
