"""Speculative n-gram decode over the slot pool vs one-token pool decode.

Same requests through the same paged scheduler twice: baseline (one
token per round, PR 1-3 path) and speculative (`cfg.spec_enabled`: a
host-side prompt-lookup drafter proposes up to `spec_k` tokens per slot
and every round verifies the whole pool's drafts in ONE multi-token
`verify_paged` dispatch).  Outputs are token-identical by construction
(drafts are only accepted when they equal the model's own greedy
argmax); what changes is dispatches per generated token.

Two workloads: **repetitive** prompts (short token cycles — greedy
decode of the reduced model locks onto cycles, so the drafter keeps
proposing the right continuation and verify rounds commit several
tokens per dispatch) and **random** prompts (novel streams — drafting
mostly misses and the verify window degenerates to a one-token round,
bounding the overhead of speculation when it cannot help).

Reported per workload and mode: generated tokens, wall-clock tokens/s,
decode dispatches, dispatches/token, live KV bytes after the run, and
for spec mode the drafted/accepted counters.  Token and dispatch
counters are deterministic (greedy decode, fixed seeds) —
scripts/check_bench.py gates them against results/bench/baseline/.
"""
from __future__ import annotations

import sys
import time

from benchmarks.common import emit, save


def _prompts(kind: str, cfg, n: int, length: int):
    if kind == "repetitive":
        cycles = ([5, 9, 2, 7], [3, 3, 8], [1, 4], [6, 2, 9, 9])
        return [(cycles[i % len(cycles)] * length)[:length]
                for i in range(n)]
    return [[(37 * (i + 1) + 13 * j) % cfg.vocab for j in range(length)]
            for i in range(n)]


def _run_mode(cfg, params, prompts, spec: bool, spec_k: int,
              max_active: int, max_new: int) -> dict:
    import dataclasses

    from repro.models.lm import build_model
    from repro.serving.engine import RealEngine, Request
    from repro.serving.scheduler import Scheduler

    rcfg = dataclasses.replace(cfg, spec_enabled=spec, spec_k=spec_k)
    eng = RealEngine(rcfg, build_model(rcfg), params, max_len=256)
    sched = Scheduler(eng, max_active=max_active)
    # warm every jit trace (admission grid + pool decode / verify window)
    # with a repetitive prompt so the timed runs are compile-free
    sched.submit(Request(0, [2, 4] * 10, max_new=6))
    sched.run()
    sched.done.clear()
    d0 = sched.metrics["decode_calls"]
    sd0, sa0, sp0 = eng.spec_drafted, eng.spec_accepted, eng.spec_dispatches

    for i, p in enumerate(prompts):
        sched.submit(Request(1 + i, p, max_new=max_new))
    t0 = time.perf_counter()
    done = sched.run()
    wall = time.perf_counter() - t0

    tokens = sum(len(r.output) for r in done)
    dispatches = sched.metrics["decode_calls"] - d0
    out = {
        "generated_tokens": tokens,
        "wall_s": wall,
        "tok_s": tokens / wall if wall > 0 else 0.0,
        "decode_dispatches": dispatches,
        "dispatches_per_token": dispatches / max(1, tokens),
        "kv_bytes_live": eng.live_kv_bytes(),
    }
    if spec:
        out["drafted_tokens"] = eng.spec_drafted - sd0
        out["accepted_tokens"] = eng.spec_accepted - sa0
        out["accept_rate"] = ((eng.spec_accepted - sa0)
                              / max(1, eng.spec_drafted - sd0))
        out["spec_dispatches"] = eng.spec_dispatches - sp0
        out["spec_traces"] = eng.spec_traces
    return out


def bench_spec(spec_k: int = 4, max_active: int = 4, n_req: int = 8,
               max_new: int = 48, prompt_len: int = 48) -> dict:
    import jax

    from repro.configs import base
    from repro.models.lm import build_model

    cfg = base.get_config("gentorrent-llama3-8b").reduced()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    out = {"params": {"spec_k": spec_k, "max_active": max_active,
                      "n_req": n_req, "max_new": max_new,
                      "prompt_len": prompt_len}}
    for kind in ("repetitive", "random"):
        prompts = _prompts(kind, cfg, n_req, prompt_len)
        res = {}
        for mode, spec in (("baseline", False), ("spec", True)):
            res[mode] = _run_mode(cfg, params, prompts, spec, spec_k,
                                  max_active, max_new)
        res["speedup"] = (res["spec"]["tok_s"]
                          / max(res["baseline"]["tok_s"], 1e-9))
        res["dispatch_ratio"] = (res["spec"]["dispatches_per_token"]
                                 / max(res["baseline"]
                                       ["dispatches_per_token"], 1e-9))
        out[kind] = res
    rep = out["repetitive"]
    out["spec_lt_one_dispatch_per_token"] = (
        rep["spec"]["dispatches_per_token"] < 1.0)
    out["spec_strictly_fewer_dispatches"] = (
        rep["spec"]["decode_dispatches"]
        < rep["baseline"]["decode_dispatches"])
    return out


def _emit(res: dict):
    for kind in ("repetitive", "random"):
        r = res[kind]
        emit(f"spec_{kind}_tok_s", r["spec"]["wall_s"] * 1e6,
             {"tok_s": r["spec"]["tok_s"],
              "dispatches_per_token": r["spec"]["dispatches_per_token"],
              "accept_rate": r["spec"].get("accept_rate", 0.0)})
        emit(f"spec_{kind}_baseline_tok_s", r["baseline"]["wall_s"] * 1e6,
             {"tok_s": r["baseline"]["tok_s"],
              "dispatches_per_token":
                  r["baseline"]["dispatches_per_token"]})


def main():
    res = bench_spec()
    save("bench_spec", res)
    _emit(res)
    return res


def quick():
    """Reduced sizes for the CI artifact + regression gate."""
    res = bench_spec(n_req=4, max_new=24, prompt_len=40)
    save("bench_spec_quick", res)
    _emit(res)
    return res


if __name__ == "__main__":
    quick() if "quick" in sys.argv[1:] else main()
