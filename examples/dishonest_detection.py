"""A dishonest model node gets caught: the §3.4 verification pipeline end
to end with REAL models.

One node claims to serve the GT model but actually runs a degraded
(harshly quantized) copy to save resources.  The committee's challenge
prompts — routed through the anonymous overlay, indistinguishable from
user traffic — are answered by the impostor model; token-level PPL scoring
against each verifier's local GT copy drives its reputation below the 0.4
trust threshold within a few epochs (paper Fig 12).

    PYTHONPATH=src python examples/dishonest_detection.py
"""
import sys
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks.gt_model import greedy, impostors, trained_gt  # noqa: E402
from repro.core.consensus import Challenge, SignedResponse, \
    VerificationCommittee  # noqa: E402
from repro.core.reputation import ReputationConfig  # noqa: E402
from repro.core.verification import VerifierModel, credibility  # noqa: E402


def main():
    print("training tiny GT model (stand-in for Llama-3.1-8B)...")
    cfg, model, params, corpus = trained_gt()
    bad_params = impostors(params)["m3"]    # harsh quantization + noise

    # 4 verification nodes, each with its own GT copy (here: same weights)
    verifier = VerifierModel(cfg, model, params)

    def score_fn(pairs):
        return float(np.mean([credibility(verifier, p, r)
                              for p, r in pairs]))

    committee = VerificationCommittee(
        4, [score_fn] * 4, rep_cfg=ReputationConfig(gamma=1 / 5))

    node_params = {"honest-node": params, "cheating-node": bad_params}
    rng = np.random.default_rng(0)
    print(f"{'epoch':>5} {'leader':>6} {'honest':>8} {'cheater':>8}")
    for epoch in range(8):
        prompts = {}
        for node in node_params:
            prompts[node] = tuple(
                corpus.sample(1, 16, rng)[0, :16].tolist())
        committee.agree_challenges(
            [Challenge(n, p) for n, p in prompts.items()])

        def collect(leader_ix, challenges):
            out = []
            for c in challenges:
                # the model node cannot tell this prompt is a challenge —
                # it answers with whatever model it actually runs
                resp = greedy(model, node_params[c.model_node],
                              list(c.prompt), n=12)
                out.append(SignedResponse(c.model_node, c.prompt,
                                          tuple(resp), b"", True))
            return out

        res = committee.run_epoch(collect)
        if res.committed:
            print(f"{epoch:>5} {res.leader:>6} "
                  f"{res.reputations.get('honest-node', 0):>8.3f} "
                  f"{res.reputations.get('cheating-node', 0):>8.3f}")

    untrusted = committee.untrusted()
    print(f"\nuntrusted nodes: {untrusted}")
    assert "cheating-node" in untrusted, "the impostor must be caught"
    assert "honest-node" not in untrusted
    print("=> the cheating node was detected and marked untrusted")


if __name__ == "__main__":
    main()
