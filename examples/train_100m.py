"""Train a ~100M-parameter llama-family model for a few hundred steps with
the full training substrate: AdamW, remat, checkpointing, restart-on-
failure, int8 gradient compression.

~100M params: d_model=512, 8 layers, vocab 50304 (most params in the
embedding at this scale, as usual for small LMs).

    PYTHONPATH=src python examples/train_100m.py --steps 200
"""
import argparse
import dataclasses

import jax

from repro.configs import base
from repro.launch.train import train
from repro.models.lm import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/gentorrent_100m")
    ap.add_argument("--compress", action="store_true",
                    help="int8 gradient compression w/ error feedback")
    args = ap.parse_args()

    # ~100M-param config check
    cfg = base.get_config("gentorrent-llama3-8b").reduced()
    cfg = dataclasses.replace(cfg, d_model=512, d_head=128, n_layers=8,
                              d_ff=1408, vocab=50304)
    n = sum(x.size for x in jax.tree.leaves(
        jax.eval_shape(build_model(cfg).init, jax.random.PRNGKey(0))))
    print(f"model: {n/1e6:.1f}M params "
          f"({cfg.n_layers}L d{cfg.d_model} ff{cfg.d_ff} V{cfg.vocab})")

    out = train("gentorrent-llama3-8b", steps=args.steps, batch=args.batch,
                seq=args.seq, ckpt_dir=args.ckpt_dir, d_model=0, layers=0,
                lr=3e-3, compress=args.compress)
    print(f"loss: {out['first_loss']:.3f} -> {out['final_loss']:.3f} "
          f"({out['tokens_per_s']:,.0f} tok/s)")
    assert out["final_loss"] < out["first_loss"]


if __name__ == "__main__":
    main()
