"""End-to-end serving driver: a full GenTorrent deployment under a
realistic batched workload — the paper's §5 testbed in miniature.

8 model nodes on two hardware tiers, 32 users, verification committee of 4
running Tendermint-style epochs with VRF leader election, ToolUse/Mixed
workloads at a Poisson rate, churn on the user population.

    PYTHONPATH=src python examples/serve_overlay.py [--requests 150]
"""
import argparse
from collections import Counter

import numpy as np

from repro.core.consensus import Challenge
from repro.net.simnet import ChurnProcess
from repro.overlay.network import OverlayConfig, build_overlay
from repro.training.data import MixedWorkload, poisson_arrivals


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=150)
    ap.add_argument("--rate", type=float, default=2.0)
    args = ap.parse_args()

    # score_fns: committee members score by response plausibility; the
    # simulation's model nodes echo deterministic outputs, so the committee
    # sees consistent scores (real-LLM scoring: examples/dishonest_detection)
    def score_fn(pairs):
        return float(np.mean([0.85 if len(r) > 0 else 0.0
                              for _, r in pairs]))

    ov = build_overlay(
        OverlayConfig(n_users=32, n_models=8, use_crypto=False, seed=0,
                      hw_scores=[4, 4, 4, 4, 8, 8, 8, 8]),
        score_fns=[score_fn] * 4)
    net = ov.net

    # --- workload ---
    gen = MixedWorkload(seed=1)
    arrivals = poisson_arrivals(args.rate, args.requests, seed=2, t0=10.0)
    done = []
    rng = np.random.default_rng(3)
    for t in arrivals:
        q = gen.sample()
        u = ov.users[int(rng.integers(0, len(ov.users)))]
        u.on_response = lambda _n, p: done.append(p)
        net.call_at(t, lambda u=u, q=q: u.send_prompt(
            net, q.tokens, session=f"s{q.prefix_id}",
            extra_meta={"max_new": q.max_new}))

    # --- churn on half the user population ---
    churn = ChurnProcess(net, [u.node_id for u in ov.users[16:]],
                         rate_per_min=6, seed=4)
    churn.start()

    # --- verification epochs in the background ---
    committee = ov.committee
    epoch_results = []

    def run_epoch():
        prompts = [tuple(int(x) for x in rng.integers(0, 1000, 12))
                   for _ in ov.models]
        committee.agree_challenges(
            [Challenge(m.node_id, p) for m, p in zip(ov.models, prompts)])

        def collect(leader_ix, challenges):
            from repro.core.consensus import SignedResponse
            return [SignedResponse(c.model_node, c.prompt,
                                   tuple(range(8)), b"", True)
                    for c in challenges]

        epoch_results.append(committee.run_epoch(collect))
        net.call_after(30.0, run_epoch)

    net.call_after(15.0, run_epoch)
    net.run_until(arrivals[-1] + 300)

    # --- report ---
    served = Counter()
    ttfts, totals = [], []
    for m in ov.models:
        served[m.node_id] = m.metrics["served"]
        ttfts += m.metrics["ttft"]
        totals += m.metrics["total"]
    print(f"completed {len(done)}/{args.requests} requests")
    print(f"served spread: {dict(served)}")
    print(f"TTFT avg {np.mean(ttfts):.2f}s p99 {np.percentile(ttfts, 99):.2f}s"
          f" | total avg {np.mean(totals):.2f}s")
    hits = sum(m.metrics['cache_hits'] for m in ov.models)
    print(f"HR-tree cache-affinity decisions: {hits}")
    print(f"verification epochs committed: "
          f"{sum(1 for e in epoch_results if e.committed)}/{len(epoch_results)}")
    print(f"reputations: { {k: round(v.score, 3) for k, v in committee.reputation.nodes.items()} }")
    assert len(done) >= args.requests * 0.8


if __name__ == "__main__":
    main()
