"""Quickstart: the GenTorrent pipeline in one file.

1. build a tiny LM and a serving engine (the thing every model node runs)
2. wrap it in a decentralized overlay: users, relays, model nodes
3. send anonymous prompts through onion paths as S-IDA cloves
4. watch HR-tree forwarding route shared-prefix requests to cache holders

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import base
from repro.models.lm import build_model
from repro.overlay.network import OverlayConfig, build_overlay
from repro.serving.engine import RealEngine, Request


def main():
    # ---- 1. a model node's serving engine (tiny config, real JAX model)
    cfg = base.get_config("gentorrent-llama3-8b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = RealEngine(cfg, model, params, max_len=256)
    r1 = engine.generate(Request(1, list(range(40)), max_new=8))
    r2 = engine.generate(Request(2, list(range(40)) + [7, 8], max_new=8))
    print(f"[engine] generated {len(r1.output)} tokens; "
          f"second request reused {r2.cached_tokens} cached prefix tokens")

    # ---- 2-4. the overlay
    ov = build_overlay(OverlayConfig(n_users=24, n_models=4,
                                     use_crypto=False, seed=0))
    shared_prefix = list(range(200))          # e.g. a common system prompt
    responses = []
    for i in range(6):
        u = ov.users[i]
        u.on_response = lambda _n, p: responses.append(p)
        # staggered so HR-tree state sync (5s period) can propagate
        ov.net.call_at(6.0 + 6.0 * i, lambda u=u, i=i: u.send_prompt(
            ov.net, shared_prefix + [1000 + i] * 50,
            session=f"user{i}", extra_meta={"max_new": 16}))
    ov.net.run_until(120.0)

    served = {m.node_id: m.metrics["served"] for m in ov.models}
    hits = sum(m.metrics["cache_hits"] for m in ov.models)
    print(f"[overlay] {len(responses)}/6 responses received anonymously")
    print(f"[overlay] served per node: {served}; HR-tree cache hits: {hits}")
    print(f"[overlay] token hit rates: "
          f"{[round(m.engine.prefix_cache.token_hit_rate, 2) for m in ov.models]}")
    assert len(responses) == 6


if __name__ == "__main__":
    main()
