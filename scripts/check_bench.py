#!/usr/bin/env python
"""CI bench regression gate.

Compares the quick-bench JSON artifacts in results/bench/ against the
committed baselines in results/bench/baseline/ and fails (exit 1) when a
gated metric drifts outside the tolerance (default ±30%, symmetric — a
large improvement also fails so the baseline gets refreshed on purpose
rather than ratcheting silently).

Only machine-independent metrics are gated: token counts, dispatch
counts, KV byte footprints, byte ratios.  Wall-clock throughputs live in
the same artifacts for the per-PR trajectory but are never gated — CI
runners are too noisy for a hard timing gate.

Usage:
    python scripts/check_bench.py                  # gate everything known
    python scripts/check_bench.py --tol 0.3
    python scripts/check_bench.py --update         # refresh the baseline
"""
from __future__ import annotations

import argparse
import json
import shutil
import sys
from pathlib import Path

# dotted-path metrics gated per artifact: deterministic counters only
GATED = {
    "fig18_throughput_quick.json": [
        "continuous_batching.decode_calls",
        "continuous_batching.batched_traces",
        "paged_kv.bytes_ratio_paged_over_dense",
        "paged_kv.paged.kv_pool_bytes",
    ],
    "bench_affinity_quick.json": [
        "affinity.prefill_tokens",
        "affinity.duplicate_prefill_tokens",
        "affinity.prefill_dispatches",
        "loadonly.duplicate_prefill_tokens",
        "duplicate_kv_bytes_saved",
    ],
}


def _dig(obj, path: str):
    for part in path.split("."):
        if not isinstance(obj, dict) or part not in obj:
            raise KeyError(path)
        obj = obj[part]
    return obj


def check_file(cur_path: Path, base_path: Path, keys: list,
               tol: float) -> list:
    """Returns a list of human-readable failure strings (empty = pass)."""
    if not base_path.exists():
        return [f"{base_path}: missing baseline (run with --update after "
                f"regenerating the quick benches, and commit it)"]
    cur = json.loads(cur_path.read_text())
    base = json.loads(base_path.read_text())
    fails = []
    for key in keys:
        try:
            b = float(_dig(base, key))
        except KeyError:
            fails.append(f"{base_path.name}:{key}: not in baseline")
            continue
        try:
            c = float(_dig(cur, key))
        except KeyError:
            fails.append(f"{cur_path.name}:{key}: missing from artifact")
            continue
        if b == 0:
            ok = c == 0          # a zero baseline is an exact invariant
        else:
            ok = abs(c - b) <= tol * abs(b)
        if not ok:
            fails.append(f"{cur_path.name}:{key}: {c:g} vs baseline "
                         f"{b:g} (tol ±{tol:.0%})")
    return fails


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--results", default="results/bench", type=Path)
    ap.add_argument("--baseline", default="results/bench/baseline",
                    type=Path)
    ap.add_argument("--tol", default=0.30, type=float)
    ap.add_argument("--update", action="store_true",
                    help="copy current artifacts over the baseline")
    args = ap.parse_args(argv)

    if args.update:
        args.baseline.mkdir(parents=True, exist_ok=True)
        for name in GATED:
            src = args.results / name
            if src.exists():
                shutil.copy(src, args.baseline / name)
                print(f"baseline updated: {args.baseline / name}")
        return 0

    failures = []
    for name, keys in GATED.items():
        cur = args.results / name
        if not cur.exists():
            failures.append(f"{cur}: artifact missing (did the quick bench "
                            f"run?)")
            continue
        failures += check_file(cur, args.baseline / name, keys, args.tol)
    if failures:
        print("bench regression gate FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    n = sum(len(k) for k in GATED.values())
    print(f"bench regression gate passed ({n} metrics within "
          f"±{args.tol:.0%})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
