#!/usr/bin/env python
"""CI bench regression gate.

Compares the quick-bench JSON artifacts in results/bench/ against the
committed baselines in results/bench/baseline/ and fails (exit 1) when a
gated metric drifts outside the tolerance (default ±30%, symmetric — a
large improvement also fails so the baseline gets refreshed on purpose
rather than ratcheting silently).

Only machine-independent metrics are gated: token counts, dispatch
counts, KV byte footprints, byte ratios.  Wall-clock throughputs live in
the same artifacts for the per-PR trajectory but are never gated — CI
runners are too noisy for a hard timing gate.

A ``*_quick.json`` artifact that is not registered in ``GATED`` is a
hard failure, not a skip: a new quick bench must name its deterministic
counters here and commit a baseline (``--update``), otherwise its
regressions would ride through CI unseen.

``--summary`` additionally writes a per-run markdown table (gated
counters plus ungated throughput/accept-rate highlights, current vs
baseline) to ``$GITHUB_STEP_SUMMARY`` — or stdout when unset — so a
regression is readable from the workflow page without downloading
artifacts.

Usage:
    python scripts/check_bench.py                  # gate everything known
    python scripts/check_bench.py --tol 0.3 --summary
    python scripts/check_bench.py --update         # refresh the baseline
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
from pathlib import Path

# dotted-path metrics gated per artifact: deterministic counters only
GATED = {
    "fig18_throughput_quick.json": [
        "continuous_batching.decode_calls",
        "continuous_batching.batched_traces",
        "paged_kv.bytes_ratio_paged_over_dense",
        "paged_kv.paged.kv_pool_bytes",
    ],
    "bench_affinity_quick.json": [
        "affinity.prefill_tokens",
        "affinity.duplicate_prefill_tokens",
        "affinity.prefill_dispatches",
        "loadonly.duplicate_prefill_tokens",
        "duplicate_kv_bytes_saved",
    ],
    "bench_spec_quick.json": [
        "repetitive.spec.decode_dispatches",
        "repetitive.spec.dispatches_per_token",
        "repetitive.spec.accepted_tokens",
        "repetitive.spec.kv_bytes_live",
        "repetitive.baseline.decode_dispatches",
        "random.spec.dispatches_per_token",
    ],
    "bench_migration_quick.json": [
        "replicate.prefill_tokens",
        "replicate.duplicate_prefill_tokens",
        "replicate.prefill_dispatches",
        "replicate.kv_imported_pages",
        "replicate.kv_fetches",
        "scratch.duplicate_prefill_tokens",
        "duplicate_dispatches_saved",
    ],
}

# ungated per-artifact highlights for the --summary table (wall-clock
# throughputs, ratios, accept rates — trajectory, never a gate)
SUMMARY_EXTRA = {
    "fig18_throughput_quick.json": [
        "continuous_batching.batched_tok_s",
        "continuous_batching.speedup",
    ],
    "bench_affinity_quick.json": [
        "affinity.tok_s",
        "tok_s_ratio",
    ],
    "bench_spec_quick.json": [
        "repetitive.spec.tok_s",
        "repetitive.spec.accept_rate",
        "repetitive.dispatch_ratio",
    ],
    "bench_migration_quick.json": [
        "replicate.tok_s",
        "tok_s_ratio",
        "replicate.kv_wire_bytes",
    ],
}

UPDATE_HINT = ("regenerate the quick benches, run scripts/check_bench.py "
               "--update, and commit the refreshed baseline")


def _dig(obj, path: str):
    for part in path.split("."):
        if not isinstance(obj, dict) or part not in obj:
            raise KeyError(path)
        obj = obj[part]
    return obj


def check_file(cur_path: Path, base_path: Path, keys: list,
               tol: float) -> tuple[list, list]:
    """Gate one artifact.  Returns (failures, summary rows); each row is
    (artifact, metric, current, baseline, gated, ok)."""
    if not base_path.exists():
        return [f"{base_path}: missing baseline ({UPDATE_HINT})"], []
    cur = json.loads(cur_path.read_text())
    base = json.loads(base_path.read_text())
    fails, rows = [], []
    for gated, key_list in ((True, keys),
                            (False, SUMMARY_EXTRA.get(cur_path.name, []))):
        for key in key_list:
            try:
                b = float(_dig(base, key))
            except KeyError:
                if gated:
                    fails.append(f"{base_path.name}:{key}: not in baseline")
                continue
            try:
                c = float(_dig(cur, key))
            except KeyError:
                if gated:
                    fails.append(f"{cur_path.name}:{key}: missing from "
                                 f"artifact")
                continue
            if b == 0:
                ok = c == 0          # a zero baseline is an exact invariant
            else:
                ok = abs(c - b) <= tol * abs(b)
            rows.append((cur_path.name, key, c, b, gated, ok or not gated))
            if gated and not ok:
                fails.append(f"{cur_path.name}:{key}: {c:g} vs baseline "
                             f"{b:g} (tol ±{tol:.0%})")
    return fails, rows


def unknown_artifacts(results: Path) -> list:
    """Quick-bench artifacts with no GATED registration: hard failures —
    an unregistered bench would otherwise regress silently."""
    fails = []
    for p in sorted(results.glob("*_quick.json")):
        if p.name not in GATED:
            fails.append(f"{p}: unknown quick-bench artifact — register "
                         f"its deterministic counters in check_bench."
                         f"GATED, then {UPDATE_HINT}")
    return fails


def write_summary(rows: list, failures: list, tol: float):
    """Markdown table for $GITHUB_STEP_SUMMARY (stdout when unset)."""
    lines = ["## Quick-bench summary", "",
             f"{len(failures)} gate failure(s), tolerance ±{tol:.0%} "
             f"(gated metrics only)", "",
             "| artifact | metric | current | baseline | Δ | gated | ok |",
             "|---|---|---:|---:|---:|:---:|:---:|"]
    for art, key, c, b, gated, ok in rows:
        delta = f"{(c - b) / b:+.1%}" if b else ("0%" if c == b else "n/a")
        lines.append(f"| {art} | {key} | {c:g} | {b:g} | {delta} "
                     f"| {'yes' if gated else '—'} "
                     f"| {'✅' if ok else '❌'} |")
    for f in failures:
        lines.append(f"- ❌ `{f}`")
    text = "\n".join(lines) + "\n"
    dest = os.environ.get("GITHUB_STEP_SUMMARY")
    if dest:
        with open(dest, "a") as fh:
            fh.write(text)
    else:
        print(text)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--results", default="results/bench", type=Path)
    ap.add_argument("--baseline", default="results/bench/baseline",
                    type=Path)
    ap.add_argument("--tol", default=0.30, type=float)
    ap.add_argument("--update", action="store_true",
                    help="copy current quick artifacts over the baseline")
    ap.add_argument("--summary", action="store_true",
                    help="write a markdown comparison table to "
                         "$GITHUB_STEP_SUMMARY (stdout when unset)")
    args = ap.parse_args(argv)

    if args.update:
        args.baseline.mkdir(parents=True, exist_ok=True)
        # every quick artifact, registered or not: an unknown one still
        # needs its baseline committed alongside its GATED registration
        for src in sorted(args.results.glob("*_quick.json")):
            shutil.copy(src, args.baseline / src.name)
            print(f"baseline updated: {args.baseline / src.name}")
        return 0

    failures, rows = [], []
    for name, keys in GATED.items():
        cur = args.results / name
        if not cur.exists():
            failures.append(f"{cur}: artifact missing (did the quick bench "
                            f"run?)")
            continue
        fails, file_rows = check_file(cur, args.baseline / name, keys,
                                      args.tol)
        failures += fails
        rows += file_rows
    failures += unknown_artifacts(args.results)
    if args.summary:
        write_summary(rows, failures, args.tol)
    if failures:
        print("bench regression gate FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    n = sum(len(k) for k in GATED.values())
    print(f"bench regression gate passed ({n} metrics within "
          f"±{args.tol:.0%})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
