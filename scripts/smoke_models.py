import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base
from repro.models.lm import build_model, lm_loss

names = sys.argv[1:] or base.list_configs()
for name in names:
    cfg = base.get_config(name).reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    n = sum(x.size for x in jax.tree.leaves(params))
    B, S = 2, 24
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    aux = {}
    if cfg.is_encdec:
        aux["frames"] = jax.random.normal(jax.random.PRNGKey(2),
                                          (B, S // 2, cfg.d_model), cfg.compute_dtype)
    if cfg.n_image_tokens:
        aux["image_embeds"] = jax.random.normal(
            jax.random.PRNGKey(3), (B, cfg.n_image_tokens, cfg.d_model), cfg.compute_dtype)
    logits = model.apply(params, tokens, aux=aux, block_q=8)
    assert logits.shape == (B, S, cfg.vocab), logits.shape
    assert not np.any(np.isnan(np.asarray(logits))), f"{name}: NaN in apply"
    # prefill + decode agreement with full forward
    pre_logits, cache = model.prefill(params, tokens[:, :S - 2], aux=aux,
                                      max_len=S + 4, block_q=8)
    assert not np.any(np.isnan(np.asarray(pre_logits)))
    np.testing.assert_allclose(np.asarray(pre_logits),
                               np.asarray(logits[:, S - 3]), rtol=2e-2, atol=2e-2)
    lg = pre_logits
    for t in range(S - 2, S):
        lg, cache = model.decode(params, cache, tokens[:, t:t + 1],
                                 jnp.full((B,), t, jnp.int32))
        np.testing.assert_allclose(np.asarray(lg), np.asarray(logits[:, t]),
                                   rtol=2e-2, atol=2e-2)
    # one loss/grad step
    loss, metrics = lm_loss(cfg, model, params, tokens,
                            jnp.where(tokens > 3, tokens, -1), aux=aux,
                            block_q=8)
    assert np.isfinite(float(loss)), name
    print(f"OK {name:26s} params={n:>9,} loss={float(loss):.3f}")
print("ALL OK")
