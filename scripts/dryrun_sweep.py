"""Sequential dry-run sweep: every (arch x shape x mesh) cell in its own
subprocess (fresh XLA state, resumable — cells with an existing JSON are
skipped unless FORCE=1)."""
import json
import os
import subprocess
import sys
import time
from pathlib import Path

ARCHS = [
    "whisper-base", "xlstm-1.3b", "h2o-danube-1.8b", "gentorrent-llama3-8b",
    "gemma2-9b", "llama-3.2-vision-11b", "moonshot-v1-16b-a3b", "granite-20b",
    "yi-34b", "jamba-v0.1-52b", "dbrx-132b",
]
SHAPES = ["train_4k", "decode_32k", "prefill_32k", "long_500k"]
OUT = Path("results/dryrun")
LOG = Path("results/dryrun/sweep.log")


def log(msg):
    line = f"[{time.strftime('%H:%M:%S')}] {msg}"
    print(line, flush=True)
    LOG.parent.mkdir(parents=True, exist_ok=True)
    with LOG.open("a") as f:
        f.write(line + "\n")


def main():
    force = os.environ.get("FORCE") == "1"
    cells = [(a, s, mp) for mp in (False, True) for a in ARCHS
             for s in SHAPES]
    t_all = time.time()
    for i, (arch, shape, mp) in enumerate(cells):
        mesh = "pod2x16x16" if mp else "pod16x16"
        out = OUT / mesh / f"{arch}_{shape}.json"
        if out.exists() and not force:
            st = json.loads(out.read_text()).get("status")
            if st in ("ok", "skipped"):
                continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--out", str(OUT)]
        if mp:
            cmd.append("--multi-pod")
        if force:
            cmd.append("--force")
        t0 = time.time()
        try:
            subprocess.run(cmd, capture_output=True, text=True,
                               timeout=3000,
                               env={**os.environ, "PYTHONPATH": "src"})
            status = "?"
            if out.exists():
                status = json.loads(out.read_text()).get("status")
            log(f"{i+1}/{len(cells)} {mesh} {arch} {shape}: {status} "
                f"({time.time()-t0:.0f}s)")
            if status == "error":
                err = json.loads(out.read_text()).get("error", "")
                log(f"   ERROR: {err[:200]}")
        except subprocess.TimeoutExpired:
            log(f"{i+1}/{len(cells)} {mesh} {arch} {shape}: TIMEOUT")
            out.write_text(json.dumps({
                "arch": arch, "shape": shape, "mesh": mesh,
                "status": "error", "error": "compile timeout (3000s)"}))
    log(f"sweep done in {(time.time()-t_all)/60:.1f} min")


if __name__ == "__main__":
    main()
