"""Real multi-device execution: an 8-device pjit train step with our
sharding rules, run in a subprocess (device count must be set before jax
init), plus checkpoint resharding."""
import json
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import base
    from repro.distributed import sharding
    from repro.models.lm import build_model
    from repro.training import optimizer as opt_lib, checkpoint as ckpt_lib
    from repro.training.train_step import make_train_step
    import tempfile

    cfg = base.get_config("h2o-danube-1.8b").reduced()
    # widen dims so a (4, 2) mesh divides them
    import dataclasses
    cfg = dataclasses.replace(cfg, d_model=64, d_ff=128, vocab=512)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    model = build_model(cfg)
    adamw = opt_lib.AdamWConfig(lr=1e-3)
    step = make_train_step(cfg, model, adamw, block_q=16)
    params = model.init(jax.random.PRNGKey(0))
    opt = opt_lib.init_state(params)
    p_sh = sharding.param_shardings(cfg, params, mesh, train=True)
    o_sh = {"mu": p_sh, "nu": p_sh,
            "step": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())}
    params = jax.tree.map(jax.device_put, params, p_sh)
    opt = {"mu": jax.tree.map(jax.device_put, opt["mu"], p_sh),
           "nu": jax.tree.map(jax.device_put, opt["nu"], p_sh),
           "step": opt["step"]}
    fn = jax.jit(step, in_shardings=(p_sh, o_sh, None),
                 out_shardings=(p_sh, o_sh, None))
    B, S = 8, 32
    batch = {"tokens": jnp.zeros((B, S), jnp.int32) + 3,
             "labels": jnp.ones((B, S), jnp.int32)}
    params2, opt2, m = fn(params, opt, batch)
    loss1 = float(m["loss"])

    # checkpoint on (4,2), restore resharded onto (2,4) — elastic re-mesh
    with tempfile.TemporaryDirectory() as d:
        ckpt_lib.save(d, 1, params2)
        mesh2 = jax.make_mesh((2, 4), ("data", "model"))
        p_sh2 = sharding.param_shardings(cfg, params2, mesh2, train=True)
        restored, _ = ckpt_lib.restore(d, 1, params2, shardings=p_sh2)
        same = all(np.allclose(np.asarray(a), np.asarray(b))
                   for a, b in zip(jax.tree.leaves(params2),
                                   jax.tree.leaves(restored)))
    print(json.dumps({"loss": loss1, "reshard_ok": bool(same),
                      "n_dev": jax.device_count()}))
""")


def test_8dev_train_step_and_reshard():
    env = {**os.environ, "PYTHONPATH": "src"}
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["n_dev"] == 8
    assert out["reshard_ok"]
    assert out["loss"] > 0
