"""Per-architecture reduced-config smoke tests: one forward/train step on
CPU, output shapes, no NaNs, and decode-vs-full-forward agreement.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct,
no allocation) — launch/dryrun.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base
from repro.models.lm import build_model, lm_loss

ARCHS = base.ASSIGNED + ["gentorrent-llama3-8b"]


def _aux_for(cfg, B, S, key):
    aux = {}
    if cfg.is_encdec:
        aux["frames"] = jax.random.normal(
            jax.random.fold_in(key, 1), (B, S // 2, cfg.d_model),
            cfg.compute_dtype)
    if cfg.n_image_tokens:
        aux["image_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 2), (B, cfg.n_image_tokens, cfg.d_model),
            cfg.compute_dtype)
    return aux


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke(arch):
    cfg = base.get_config(arch).reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    B, S = 2, 24
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    aux = _aux_for(cfg, B, S, jax.random.PRNGKey(2))

    logits = model.apply(params, tokens, aux=aux, block_q=8)
    assert logits.shape == (B, S, cfg.vocab)
    assert not np.any(np.isnan(np.asarray(logits))), f"{arch}: NaN"

    # prefill(S-2) + 2 decode steps must agree with the full forward
    pre_logits, cache = model.prefill(params, tokens[:, :S - 2], aux=aux,
                                      max_len=S + 4, block_q=8)
    np.testing.assert_allclose(np.asarray(pre_logits),
                               np.asarray(logits[:, S - 3]),
                               rtol=2e-2, atol=2e-2)
    lg = pre_logits
    for t in range(S - 2, S):
        lg, cache = model.decode(params, cache, tokens[:, t:t + 1],
                                 jnp.full((B,), t, jnp.int32))
        np.testing.assert_allclose(np.asarray(lg), np.asarray(logits[:, t]),
                                   rtol=2e-2, atol=2e-2)

    # one loss evaluation: finite
    loss, metrics = lm_loss(cfg, model, params, tokens,
                            jnp.where(tokens > 3, tokens, -1), aux=aux,
                            block_q=8)
    assert np.isfinite(float(loss))


def test_all_assigned_archs_registered():
    for a in base.ASSIGNED:
        cfg = base.get_config(a)
        assert cfg.n_layers % len(cfg.pattern) == 0
        assert cfg.param_counts()["total"] > 0


def test_long_context_policy():
    runnable = {a: base.get_config(a).supports_long_context
                for a in base.ASSIGNED}
    assert runnable["xlstm-1.3b"]
    assert runnable["h2o-danube-1.8b"]
    assert runnable["jamba-v0.1-52b"]
    for a in ("yi-34b", "gemma2-9b", "granite-20b", "dbrx-132b",
              "moonshot-v1-16b-a3b", "llama-3.2-vision-11b", "whisper-base"):
        assert not runnable[a], a


def test_param_counts_sane():
    # spot-check two archs against the assignment's advertised sizes
    dbrx = base.get_config("dbrx-132b").param_counts()["total"]
    assert 1.1e11 < dbrx < 1.5e11
    yi = base.get_config("yi-34b").param_counts()["total"]
    assert 3.0e10 < yi < 3.9e10
