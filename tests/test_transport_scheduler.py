"""TCP transport, wire schema, and continuous-batching scheduler tests."""
import time

import jax
import pytest

from repro.configs import base
from repro.models.lm import build_model
from repro.net import messages
from repro.net.tcp import TcpNet
from repro.serving.engine import RealEngine, Request
from repro.serving.scheduler import Scheduler


# ---------------------------------------------------------------- messages
def test_schema_validation():
    assert messages.validate({"type": "hr_sync", "from": "m0", "paths": [],
                              "active": 0, "hw": 5})
    assert not messages.validate({"type": "hr_sync", "from": "m0"})
    assert not messages.validate({"type": "bogus"})


def test_framing_roundtrip_incremental():
    msgs = [{"type": "proxy_ack", "path_id": "ab", "n": i}
            for i in range(5)]
    stream = b"".join(messages.encode(m) for m in msgs)
    dec = messages.Decoder()
    got = []
    # feed in awkward chunk sizes
    for i in range(0, len(stream), 7):
        got.extend(dec.feed(stream[i:i + 7]))
    assert got == msgs


# ---------------------------------------------------------------- tcp
class Echo:
    def __init__(self):
        self.got = []

    def on_message(self, net, src, msg):
        self.got.append((src, msg.get("n")))
        if msg.get("reply_to"):
            net.send("echo", msg["reply_to"], {"type": "proxy_ack",
                                               "path_id": "00",
                                               "n": msg["n"] + 100})


def test_tcp_roundtrip():
    net = TcpNet()
    a, b = Echo(), Echo()
    net.add_node("a", a)
    net.add_node("echo", b)
    for i in range(3):
        net.send("a", "echo", {"type": "proxy_ack", "path_id": "00",
                               "n": i, "reply_to": "a"}, 64)
    deadline = time.time() + 5
    while time.time() < deadline and len(a.got) < 3:
        time.sleep(0.02)
    net.close()
    assert sorted(n for _, n in b.got) == [0, 1, 2]
    assert sorted(n for _, n in a.got) == [100, 101, 102]


def test_tcp_send_to_dead_node_drops():
    net = TcpNet()
    net.add_node("a", Echo())
    net.send("a", "ghost", {"type": "proxy_ack", "path_id": "00"})
    assert net.dropped == 1
    net.close()


# ---------------------------------------------------------------- scheduler
@pytest.fixture(scope="module")
def engine():
    cfg = base.get_config("gentorrent-llama3-8b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return RealEngine(cfg, model, params, max_len=160)


def test_scheduler_completes_all(engine):
    s = Scheduler(engine, max_active=3)
    for i in range(6):
        s.submit(Request(i, [7] * 20 + [i], max_new=6))
    done = s.run()
    assert len(done) == 6
    assert all(len(r.output) == 6 for r in done)
    assert s.metrics["completed"] == 6


def test_scheduler_matches_sequential_engine(engine):
    prompt = list(range(30))
    r_seq = engine.generate(Request(100, prompt, max_new=6))
    s = Scheduler(engine, max_active=2)
    s.submit(Request(101, prompt, max_new=6))
    done = s.run()
    assert done[0].output == r_seq.output


def test_scheduler_prefix_cache_reuse(engine):
    shared = [3] * 40
    s = Scheduler(engine, max_active=2)
    s.submit(Request(200, shared + [1], max_new=4))
    s.run()
    s.submit(Request(201, shared + [2], max_new=4))
    done = s.run()
    assert done[-1].cached_tokens >= 32
