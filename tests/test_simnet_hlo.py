"""SimNet semantics + HLO analyzer correctness (trip-count scaling)."""
import jax
import jax.numpy as jnp

from repro.distributed import hlo_analysis
from repro.net.simnet import SimNet


class Recorder:
    def __init__(self):
        self.got = []

    def on_message(self, net, src, msg):
        self.got.append((net.t, src, msg))


def test_simnet_latency_and_order():
    net = SimNet(default_latency=0.1, bandwidth_bps=1e6)
    r = Recorder()
    net.add_node("b", r)
    net.send("a", "b", {"i": 1}, size_bytes=100)
    net.send("a", "b", {"i": 2}, size_bytes=100_000)  # slower (bandwidth)
    net.run_until(1.0)
    assert [m["i"] for _, _, m in r.got] == [1, 2]
    assert abs(r.got[0][0] - 0.1001) < 1e-3
    assert r.got[1][0] > r.got[0][0]


def test_simnet_drop_to_dead_node():
    net = SimNet()
    net.send("a", "ghost", {"x": 1})
    net.run_until(1.0)
    assert net.dropped == 1 and net.delivered == 0


def test_simnet_timer_ordering():
    net = SimNet()
    seen = []
    net.call_after(0.5, lambda: seen.append("late"))
    net.call_after(0.1, lambda: seen.append("early"))
    net.run_until(1.0)
    assert seen == ["early", "late"]


def test_hlo_analyzer_scales_loop_bodies():
    w = jnp.zeros((128, 128), jnp.float32)

    def f(x):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((32, 128), jnp.float32)).compile()
    r = hlo_analysis.analyze(c.as_text(), 1)
    expect = 2 * 32 * 128 * 128 * 7
    assert abs(r["flops"] - expect) / expect < 0.01
    # XLA's own analysis counts the body once — documents why we re-derive
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    assert ca["flops"] < expect / 2


def test_hlo_analyzer_matches_cost_analysis_loop_free():
    a = jnp.zeros((64, 256), jnp.float32)
    w1 = jnp.zeros((256, 512), jnp.float32)
    w2 = jnp.zeros((512, 64), jnp.float32)
    f = jax.jit(lambda x: jax.nn.relu(x @ w1) @ w2)
    c = f.lower(jax.ShapeDtypeStruct(a.shape, a.dtype)).compile()
    r = hlo_analysis.analyze(c.as_text(), 1)
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    assert abs(r["flops"] - ca["flops"]) / ca["flops"] < 0.05


def test_collective_ring_factors():
    txt = """
ENTRY %main.1 (p0: f32[1024]) -> f32[1024] {
  %p0 = f32[1024]{0} parameter(0)
  ROOT %ar = f32[1024]{0} all-reduce(%p0), replica_groups=[4,8]<=[32], to_apply=%add
}
"""
    r = hlo_analysis.analyze(txt, 32)
    # ring all-reduce: 2 * 4096 bytes * 7/8
    assert abs(r["coll_eff_bytes"] - 2 * 4096 * 7 / 8) < 1e-6
