"""Overlay integration: anonymity plumbing, S-IDA delivery under drops,
HR-tree forwarding, session affinity, churn survival, verification e2e."""
import random

import pytest

from repro.core import anonymity
from repro.net.simnet import ChurnProcess
from repro.overlay.network import OverlayConfig, build_overlay


@pytest.fixture(scope="module")
def overlay():
    return build_overlay(OverlayConfig(n_users=30, n_models=4,
                                       use_crypto=False, seed=3))


def _roundtrip(ov, i, tokens, session=None):
    got = []
    u = ov.users[i]
    u.on_response = lambda _n, p: got.append(p)
    u.send_prompt(ov.net, tokens, session=session,
                  extra_meta={"max_new": 4})
    ov.net.run_until(ov.net.t + 60)
    return got


def test_request_response_roundtrip(overlay):
    got = _roundtrip(overlay, 0, [1, 2, 3] * 30)
    assert len(got) == 1
    assert got[0]["output"]


def test_model_never_learns_user_identity(overlay):
    """The recovered request payload at the model node must not contain the
    user id — only proxy ids."""
    seen = {}
    m = overlay.models[0]
    orig = m._process

    def spy(net, payload, forwarded=False):
        seen.update(payload)
        return orig(net, payload, forwarded=forwarded)

    m._process = spy
    _roundtrip(overlay, 5, [9] * 64)
    m._process = orig
    if seen:  # our request may have landed on another node; check fields
        blob = str(seen)
        assert "u5" not in blob.replace("u5:", "")  # only in proxy ids? no:
    # structural check: payload schema has no sender field
    assert "sender" not in seen and "user" not in seen


def test_session_affinity(overlay):
    got1 = _roundtrip(overlay, 7, [4] * 100, session="sess-x")
    assert got1
    server1 = got1[0]["server"]
    got2 = _roundtrip(overlay, 7, [4] * 100 + [5, 6], session="sess-x")
    assert got2 and got2[0]["server"] == server1


def test_clove_delivery_survives_path_failures():
    ov = build_overlay(OverlayConfig(n_users=30, n_models=2, n_proxies=6,
                                     sida_n=4, sida_k=3, use_crypto=False,
                                     seed=11))
    u = ov.users[0]
    # kill one relay on one of the chosen paths: with n=4, k=3, one lost
    # path must not prevent recovery
    victim = None
    for p in u.live_paths():
        nxt = p.first_hop
        if nxt != u.node_id:
            victim = nxt
            break
    ov.net.remove_node(victim)
    got = []
    u.on_response = lambda _n, pl: got.append(pl)
    u.send_prompt(ov.net, [3] * 50, extra_meta={"max_new": 4})
    ov.net.run_until(ov.net.t + 60)
    assert len(got) == 1, "k-of-n S-IDA must survive one dead path"


def test_hrtree_forwarding_cache_affinity():
    ov = build_overlay(OverlayConfig(n_users=24, n_models=4,
                                     use_crypto=False, seed=5,
                                     sync_every=2.0))
    shared = list(range(200))
    # first wave: populate some node's cache + let state sync propagate
    _roundtrip(ov, 0, shared + [11] * 40)
    ov.net.run_until(ov.net.t + 10)
    # second wave from DIFFERENT users, sharing the prefix
    for i in (3, 6, 9):
        _roundtrip(ov, i, shared + [100 + i] * 40)
    hits = sum(m.metrics["cache_hits"] for m in ov.models)
    assert hits >= 2, "HR-tree should route shared-prefix queries together"


def test_churn_survival_rate():
    ov = build_overlay(OverlayConfig(n_users=40, n_models=2, n_proxies=6,
                                     use_crypto=False, seed=7))
    pool = [u.node_id for u in ov.users[10:]]  # churnable users
    # ~25%/min relative churn — well above the paper's 6.4%/min regime
    churn = ChurnProcess(ov.net, pool, rate_per_min=10, seed=2)
    churn.start()
    ok = 0
    total = 10
    for i in range(total):
        u = ov.users[i % 10]
        u.maintain(ov.net)          # periodic proxy refresh (§5.2)
        ov.net.run_until(ov.net.t + 2)
        got = _roundtrip(ov, i % 10, [i] * 60)
        ok += 1 if got else 0
    assert ok >= total * 0.6  # redundancy keeps most requests alive


def test_anonymity_metric_ordering():
    rng = random.Random(0)
    N, f = 2000, 0.05
    gt = sum(anonymity.gentorrent_anonymity(N, f, 4, 3, rng)
             for _ in range(30)) / 30
    on = sum(anonymity.onion_anonymity(N, f, 3, rng) for _ in range(30)) / 30
    assert 0.0 <= on <= 1.0
    gc = sum(anonymity.garlic_anonymity(N, f, 4, 3, rng)
             for _ in range(30)) / 30
    assert gt > 0.9
    assert gt >= gc  # per Fig 9 ordering
