"""Reputation dynamics (§3.4) and Tendermint-style committee tests."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.consensus import Challenge, SignedResponse, \
    VerificationCommittee
from repro.core.reputation import ReputationConfig, ReputationTracker


def test_good_node_converges_high():
    tr = ReputationTracker()
    for _ in range(20):
        tr.update("good", 0.8)
    assert tr.nodes["good"].score > 0.75
    assert "good" in tr.trusted()


def test_bad_node_punished_below_threshold():
    tr = ReputationTracker()
    for _ in range(6):
        tr.update("bad", 0.15)
    assert tr.nodes["bad"].score < 0.4  # untrusted within ~5 epochs (Fig 12)


def test_punishment_stronger_than_plain_ema():
    cfg = ReputationConfig()
    tr_pun = ReputationTracker(cfg)
    # plain EMA with the same inputs
    r = cfg.initial
    for _ in range(6):
        tr_pun.update("x", 0.2)
        r = cfg.alpha * r + cfg.beta * 0.2
    assert tr_pun.nodes["x"].score < r


def test_recovery_requires_consistency():
    tr = ReputationTracker()
    for _ in range(6):
        tr.update("n", 0.1)
    low = tr.nodes["n"].score
    tr.update("n", 0.9)  # single good epoch
    assert tr.nodes["n"].score < 0.75  # one good epoch cannot whitewash
    assert tr.nodes["n"].score >= low


@given(st.lists(st.floats(min_value=0.0, max_value=1.0), min_size=1,
                max_size=40))
@settings(max_examples=30, deadline=None)
def test_reputation_bounded(cs):
    tr = ReputationTracker()
    for c in cs:
        s = tr.update("n", c)
        assert 0.0 <= s <= 1.0


# ---------------------------------------------------------------- committee
def _mk_committee(n=4, spread=0.0, byzantine=None):
    # score_fns keyed on response content: good responses score 0.8
    def make_fn(i):
        def fn(pairs):
            base = np.mean([0.8 if sum(r) % 2 == 0 else 0.2
                            for _, r in pairs])
            return float(base + spread * i)
        return fn
    return VerificationCommittee(n, [make_fn(i) for i in range(n)],
                                 byzantine=byzantine)


def _collect_factory(good=True):
    def collect(leader_ix, challenges):
        out = []
        for c in challenges:
            resp = (2, 2) if good else (1, 2)   # even sum = good
            out.append(SignedResponse(c.model_node, c.prompt, resp, b"", True))
        return out
    return collect


def test_epoch_commits_and_updates_reputation():
    com = _mk_committee()
    com.agree_challenges([Challenge("m0", (1, 2, 3)),
                          Challenge("m1", (4, 5, 6))])
    res = com.run_epoch(_collect_factory(good=True))
    assert res.committed
    assert set(res.reputations) == {"m0", "m1"}
    assert all(v > 0.5 for v in res.reputations.values())


def test_prompt_mismatch_aborts():
    com = _mk_committee()
    com.agree_challenges([Challenge("m0", (1, 2, 3))])

    def bad_collect(leader_ix, challenges):
        return [SignedResponse("m0", (9, 9, 9), (2, 2), b"", True)]

    res = com.run_epoch(bad_collect)
    assert not res.committed and "mismatch" in res.aborted_reason


def test_bad_signature_aborts():
    com = _mk_committee()
    com.agree_challenges([Challenge("m0", (1, 2, 3))])

    def bad_collect(leader_ix, challenges):
        return [SignedResponse("m0", (1, 2, 3), (2, 2), b"", False)]

    res = com.run_epoch(bad_collect)
    assert not res.committed and "signature" in res.aborted_reason


def test_byzantine_leader_epoch_aborts_then_recovers():
    com = _mk_committee(n=4)
    com.agree_challenges([Challenge("m0", (1, 2, 3))])
    # find which epoch gets a byzantine leader by marking all leaders bad
    com.byzantine = {com.leader()}
    res1 = com.run_epoch(_collect_factory(good=True))
    assert not res1.committed
    # next epoch: new leader (commit hash advanced); clear byzantine set
    com.byzantine = set()
    com.agree_challenges([Challenge("m0", (7, 8, 9))])
    res2 = com.run_epoch(_collect_factory(good=True))
    assert res2.committed


def test_unique_challenge_prompts_enforced():
    com = _mk_committee()
    with pytest.raises(AssertionError):
        com.agree_challenges([Challenge("m0", (1, 2)),
                              Challenge("m1", (1, 2))])


def test_dishonest_model_loses_trust_over_epochs():
    com = _mk_committee()
    for e in range(8):
        com.agree_challenges([Challenge("good", (e, e, 2 * e)),
                              Challenge("bad", (e, e, 2 * e + 1))])

        def collect(leader_ix, challenges):
            out = []
            for c in challenges:
                resp = (2, 2) if c.model_node == "good" else (1, 2)
                out.append(SignedResponse(c.model_node, c.prompt, resp,
                                          b"", True))
            return out

        com.run_epoch(collect)
    assert "bad" in com.untrusted()
    assert "good" not in com.untrusted()
