"""Ed25519 / X25519 / VRF / onion-establishment tests."""
import pytest

from repro.core import ed25519, onion, vrf


def test_ed25519_sign_verify():
    sk = ed25519.SigningKey(b"\x01" * 32)
    sig = sk.sign(b"hello")
    assert ed25519.verify(sk.public, b"hello", sig)
    assert not ed25519.verify(sk.public, b"hellO", sig)
    assert not ed25519.verify(sk.public, b"hello", sig[:-1] + b"\x00")


def test_x25519_rfc7748_vector():
    k = bytes.fromhex("a546e36bf0527c9d3b16154b82465edd"
                      "62144c0ac1fc5a18506a2244ba449ac4")
    u = bytes.fromhex("e6db6867583030db3594c1a424b15f7c"
                      "726624ec26b3353b10a903a6d0ab1c4c")
    out = ed25519.x25519(k, u)
    assert out == bytes.fromhex("c3da55379de9c6908e94ea4df28d084f"
                                "32eccf03491c71f754b4075577a28552")


def test_dh_agreement():
    a_sk, a_pub = ed25519.dh_keypair(b"\x02" * 32)
    b_sk, b_pub = ed25519.dh_keypair(b"\x03" * 32)
    assert ed25519.dh_shared(a_sk, b_pub) == ed25519.dh_shared(b_sk, a_pub)


def test_vrf_prove_verify():
    sk = ed25519.SigningKey(b"\x04" * 32)
    beta, proof = vrf.prove(sk, b"epoch-seed")
    assert vrf.verify(sk.public, b"epoch-seed", beta, proof)
    assert not vrf.verify(sk.public, b"other-seed", beta, proof)
    sk2 = ed25519.SigningKey(b"\x05" * 32)
    assert not vrf.verify(sk2.public, b"epoch-seed", beta, proof)


def test_vrf_leader_uniform():
    from collections import Counter
    c = Counter(vrf.leader_index([bytes([i]) * 4], 4) for i in range(64))
    assert len(c) == 4  # all leader slots reachable


def test_onion_establishment_peel_chain():
    hops, sks = [], {}
    for i in range(3):
        s, p = ed25519.dh_keypair(bytes([10 + i]) * 32)
        hops.append((f"r{i}", p))
        sks[f"r{i}"] = s
    pid, first, blob = onion.build_establishment("user", b"\xAA" * 32, hops)
    assert first == "r0"
    ids = ["user", "r0", "r1", "r2"]
    for i in range(3):
        p, pred, succ, inner, pay = onion.peel_establishment(blob, sks[f"r{i}"])
        assert p == pid
        assert pred == ids[i]
        if i < 2:
            assert succ == ids[i + 2]
            blob = inner
        else:
            assert succ is None
            assert pay[8:] == b"\xAA" * 32  # nonce || user pub


def test_onion_wrong_key_fails_or_garbage():
    hops, sks = [], {}
    for i in range(3):
        s, p = ed25519.dh_keypair(bytes([20 + i]) * 32)
        hops.append((f"r{i}", p))
        sks[f"r{i}"] = s
    _, _, blob = onion.build_establishment("user", b"\xBB" * 32, hops)
    wrong_sk, _ = ed25519.dh_keypair(b"\x99" * 32)
    with pytest.raises(Exception):
        pid, pred, succ, inner, pay = onion.peel_establishment(blob, wrong_sk)
        # decryption with the wrong key must not produce a valid layer
        assert succ in ("r1",) and pred == "user"


def test_relay_state_bidirectional():
    rs = onion.RelayState()
    rs.install(b"p" * 16, "prev", "next")
    assert rs.next_hop(b"p" * 16, "prev") == "next"
    assert rs.next_hop(b"p" * 16, "next") == "prev"
    assert rs.next_hop(b"p" * 16, "outside") == "prev"
    assert rs.next_hop(b"q" * 16, "prev") is None
