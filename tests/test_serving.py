"""Serving engine + prefix cache tests."""
import jax
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import base
from repro.models.lm import build_model
from repro.serving.engine import (LatencyEngine, LatencyEngineConfig,
                                  RealEngine, Request)
from repro.serving.prefix_cache import PrefixCache, _chain_hashes


# ---------------------------------------------------------------- prefix cache
def test_match_longest_block_aligned_prefix():
    pc = PrefixCache(block=8)
    toks = list(range(64))
    pc.insert(toks, handle="H64", nbytes=100)
    ln, e = pc.match(toks + [999] * 8)
    assert ln == 64 and e.handle == "H64"
    ln, e = pc.match(toks[:32] + [5] * 32)
    assert e is None or ln <= 32


def test_no_false_prefix_match():
    pc = PrefixCache(block=8)
    pc.insert(list(range(64)), handle="A", nbytes=10)
    ln, e = pc.match([1000 + i for i in range(64)])
    assert e is None and ln == 0


def test_lru_eviction_by_bytes():
    pc = PrefixCache(max_bytes=250, block=8)
    pc.insert(list(range(16)), "A", 100)
    pc.insert(list(range(100, 116)), "B", 100)
    pc.match(list(range(16)))             # touch A
    pc.insert(list(range(200, 216)), "C", 100)  # evicts B (LRU)
    assert pc.match(list(range(16)))[1] is not None
    assert pc.match(list(range(100, 116)))[1] is None


@given(st.lists(st.integers(0, 100), min_size=8, max_size=80))
@settings(max_examples=25, deadline=None)
def test_chain_hash_prefix_property(tokens):
    """chain hash at depth d depends only on the first d blocks."""
    h1 = _chain_hashes(tokens, block=8)
    h2 = _chain_hashes(tokens + [7, 7, 7], block=8)
    for a, b in zip(h1, h2):
        assert a == b


# ---------------------------------------------------------------- real engine
@pytest.fixture(scope="module")
def tiny_engine():
    cfg = base.get_config("gentorrent-llama3-8b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, RealEngine(cfg, model, params, max_len=128)


def test_real_engine_generates(tiny_engine):
    cfg, eng = tiny_engine
    r = eng.generate(Request(1, list(range(20)), max_new=8))
    assert len(r.output) == 8
    assert all(0 <= t < cfg.vocab for t in r.output)


def test_real_engine_prefix_reuse_identical_output(tiny_engine):
    cfg, eng = tiny_engine
    prompt = list(range(40))
    r1 = eng.generate(Request(2, prompt, max_new=8))
    assert r1.cached_tokens == 0
    # same prompt again: cache hit, identical greedy output
    r2 = eng.generate(Request(3, prompt, max_new=8))
    assert r2.cached_tokens >= 32
    assert r2.output == r1.output


def test_real_engine_shared_prefix_reuse(tiny_engine):
    cfg, eng = tiny_engine
    shared = [7] * 40
    eng.generate(Request(4, shared + [1, 2, 3], max_new=4))
    r = eng.generate(Request(5, shared + [4, 5, 6], max_new=4))
    assert r.cached_tokens >= 32  # reused the shared 40-token prefix


# ---------------------------------------------------------------- latency engine
def test_latency_engine_slots_queue():
    e = LatencyEngine(LatencyEngineConfig(prefill_tps=1000, decode_tps=100,
                                          batch_slots=2, overhead_s=0.0))
    t1, _ = e.service_times(1000, 0, 0, now=0.0)      # 1s prefill
    t2, _ = e.service_times(1000, 0, 0, now=0.0)
    t3, _ = e.service_times(1000, 0, 0, now=0.0)      # must wait for a slot
    assert t1 == pytest.approx(1.0, rel=0.2)
    assert t3 > t1


def test_latency_engine_cache_reduces_ttft():
    e = LatencyEngine(LatencyEngineConfig(prefill_tps=1000, decode_tps=100,
                                          batch_slots=8, overhead_s=0.0))
    cold, _ = e.service_times(2000, 0, 10, now=0.0)
    warm, _ = e.service_times(2000, 1900, 10, now=100.0)
    assert warm < cold * 0.2


def test_latency_engine_hw_score_scales():
    slow = LatencyEngine(LatencyEngineConfig(hw_score=2.0))
    fast = LatencyEngine(LatencyEngineConfig(hw_score=10.0))
    ts, _ = slow.service_times(4000, 0, 50, now=0.0)
    tf, _ = fast.service_times(4000, 0, 50, now=0.0)
    assert tf < ts
