"""Speculative n-gram decode over the slot pool: token parity with
non-speculative greedy decoding, one-verify-dispatch-per-round and
single-trace guarantees, drafter determinism, and rollback safety.

Deliberately hypothesis-free so it runs even without dev extras installed.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import base
from repro.models.lm import build_model
from repro.serving.engine import NgramDrafter, RealEngine, Request
from repro.serving.scheduler import Scheduler


@pytest.fixture(scope="module")
def gt():
    cfg = base.get_config("gentorrent-llama3-8b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _spec_engine(gt, spec_k=4, **kw):
    cfg, _, params = gt
    scfg = dataclasses.replace(cfg, spec_enabled=True, spec_k=spec_k)
    return RealEngine(scfg, build_model(scfg), params, **kw)


def _mixed_prompts(cfg):
    """Repetitive (cycle) prompts interleaved with pseudo-random ones —
    the former draft well, the latter exercise the zero-accept path."""
    rep = [5, 9, 2, 7] * 10
    return [rep,
            [(37 * 1 + j) % cfg.vocab for j in range(20)],
            rep[:36],
            [(37 * 3 + j) % cfg.vocab for j in range(44)],
            [3] * 30,
            [(37 * 5 + j) % cfg.vocab for j in range(33)]]


# ------------------------------------------------------------- drafter
def test_drafter_proposes_continuation_of_last_match():
    d = NgramDrafter([1, 2, 3, 4, 1, 2, 3])
    assert d.draft(3) == [4, 1, 2]          # trigram [1,2,3] seen -> 4...
    d.extend([9])                           # context now ends ...3, 9
    assert d.draft(2) == []                 # 9 never seen before
    d.extend([1, 2, 3])
    # most recent occurrence wins: [1,2,3] was last followed by 9
    assert d.draft(2) == [9, 1]


def test_drafter_deterministic_and_capped():
    toks = [7, 8, 7, 8, 7]
    a, b = NgramDrafter(toks), NgramDrafter(toks)
    assert a.draft(4) == b.draft(4)
    assert a.draft(0) == []
    assert len(a.draft(2)) <= 2
    assert NgramDrafter([]).draft(3) == []


# ------------------------------------------------------------------ parity
def test_spec_matches_nonspec_greedy(gt):
    """The acceptance check: speculative decode is token-identical to
    non-speculative greedy decoding over mixed repetitive/non-repetitive
    prompts, and drafts actually get accepted on the repetitive ones."""
    cfg, model, params = gt
    prompts = _mixed_prompts(cfg)
    ref_eng = RealEngine(cfg, model, params, max_len=128)
    s0 = Scheduler(ref_eng, max_active=4)
    for i, p in enumerate(prompts):
        s0.submit(Request(i, p, max_new=24))
    ref = {r.req_id: r.output for r in s0.run()}

    eng = _spec_engine(gt, max_len=128)
    assert eng.spec
    s1 = Scheduler(eng, max_active=4)
    assert s1.spec
    for i, p in enumerate(prompts):
        s1.submit(Request(i, p, max_new=24))
    out = {r.req_id: r.output for r in s1.run()}
    assert out == ref
    # the reduced model's greedy decode cycles, so the n-gram drafter must
    # have landed accepts — speculation did real work, not just parity
    assert eng.spec_accepted > 0
    assert eng.spec_dispatches < s0.metrics["decode_calls"]
    assert eng.spec_traces == 1
    eng.allocator.check()


def test_spec_matches_sequential_generate(gt):
    """Single-request pools: spec decode equals the sequential paged
    ``generate`` path exactly, including eos/max_len termination."""
    cfg, model, params = gt
    prompts = [[4, 6] * 12, [(13 * j + 5) % cfg.vocab for j in range(21)]]
    seq = RealEngine(cfg, model, params, max_len=64)
    ref = [seq.generate(Request(i, p, max_new=40)).output
           for i, p in enumerate(prompts)]
    for i, p in enumerate(prompts):
        eng = _spec_engine(gt, max_len=64)
        s = Scheduler(eng, max_active=1)
        s.submit(Request(0, p, max_new=40))
        assert s.run()[0].output == ref[i]


def test_spec_eos_mid_window(gt):
    """A draft token equal to eos must finish the row exactly where the
    non-speculative path would, with the same prefix-cache coverage."""
    cfg, model, params = gt
    prompt = [5, 9, 2, 7] * 10
    ref_eng = RealEngine(cfg, model, params, max_len=128)
    base_out = ref_eng.generate(Request(0, prompt, max_new=24)).output
    # pick an eos that actually appears mid-stream (the cycle repeats)
    eos = base_out[7]
    ref = RealEngine(cfg, model, params, max_len=128).generate(
        Request(0, prompt, max_new=24, eos_id=eos)).output

    eng = _spec_engine(gt, max_len=128)
    s = Scheduler(eng, max_active=2)
    s.submit(Request(0, prompt, max_new=24, eos_id=eos))
    got = s.run()[0].output
    assert got == ref and got[-1] == eos
    eng.allocator.check()


def test_spec_with_prefix_cache_hit(gt):
    """Aliased-page admission + speculative decode: the verify window
    must never write into aliased prefix pages (writes start at the
    divergence position), and outputs stay parity-exact."""
    cfg, model, params = gt
    shared = [3] * 64                                  # two full blocks
    ref_eng = RealEngine(cfg, model, params, max_len=128)
    ref_eng.generate(Request(0, shared + [5], max_new=2))
    ref = ref_eng.generate(Request(1, shared + [8] * 4, max_new=12)).output

    eng = _spec_engine(gt, max_len=128)
    s = Scheduler(eng, max_active=2)
    s.submit(Request(0, shared + [5], max_new=2))
    s.run()
    _, entry = eng.prefix_cache.peek(shared)
    pages = list(entry.handle.pages)
    before = [np.asarray(leaf[:, pages]) for leaf in
              jax.tree.leaves(eng.arena)]
    s.submit(Request(1, shared + [8] * 4, max_new=12))
    out = {r.req_id: r.output for r in s.run()}[1]
    assert out == ref
    after = [np.asarray(leaf[:, pages]) for leaf in
             jax.tree.leaves(eng.arena)]
    for b, a in zip(before, after):
        np.testing.assert_array_equal(b, a)


# ---------------------------------------------------------- dispatch count
def test_step_issues_exactly_one_pool_dispatch(gt):
    """Every scheduler round in spec mode is ONE pool dispatch — the
    verify window when any slot drafted, the cached one-token pool decode
    when none did — never a per-request decode, across all occupancies."""
    eng = _spec_engine(gt, max_len=128)
    s = Scheduler(eng, max_active=3)
    for i in range(3):
        s.submit(Request(i, [7, 2] * 6 + [i], max_new=9, eos_id=-1))
    s.step()               # admissions + first pool round
    assert len(s.active) == 3

    pool_calls = []
    real_verify = eng._verify_paged_batched
    eng._verify_paged_batched = lambda *a: (pool_calls.append("verify")
                                            or real_verify(*a))
    real_decode = eng._decode_batched
    eng._decode_batched = lambda *a: (pool_calls.append("fallback")
                                      or real_decode(*a))

    def _no_single(*a):    # pragma: no cover - failure path
        raise AssertionError("per-request decode dispatched from step()")
    eng._decode_paged = _no_single

    while s.active:
        n0 = len(pool_calls)
        s.step()
        made = len(pool_calls) - n0
        # exactly one pool dispatch whenever any slot survives the round,
        # zero when the round retires every remaining slot
        assert made == (1 if s.active else 0)
    assert s.metrics["completed"] == 3
    assert eng.spec_traces == 1
    assert (eng.spec_dispatches + eng.spec_draftless_rounds
            == s.metrics["decode_calls"])


def test_draftless_round_falls_back_to_pool_decode(gt):
    """A round where NO slot drafted must issue the cached one-token pool
    decode instead of the full (B, spec_k+1, V) verify dispatch: exactly
    two cached traces total (one verify window + one pool decode), and
    outputs stay token-identical to the non-speculative scheduler."""
    cfg, model, params = gt
    # pseudo-random prompts draft nothing at first (novel text), the
    # repetitive one drafts well: the run must mix fallback and verify
    # rounds in one pool
    prompts = [[(29 * (i + 1) + j) % cfg.vocab for j in range(17 + 5 * i)]
               for i in range(2)] + [[5, 9, 2, 7] * 10]
    ref_eng = RealEngine(cfg, model, params, max_len=128)
    s0 = Scheduler(ref_eng, max_active=3)
    for i, p in enumerate(prompts):
        s0.submit(Request(i, p, max_new=16))
    ref = {r.req_id: r.output for r in s0.run()}

    eng = _spec_engine(gt, max_len=128)
    s1 = Scheduler(eng, max_active=3)
    for i, p in enumerate(prompts):
        s1.submit(Request(i, p, max_new=16))
    out = {r.req_id: r.output for r in s1.run()}
    assert out == ref
    assert eng.spec_draftless_rounds > 0          # fallback really fired
    assert eng.spec_dispatches > 0                # and so did verify
    # exactly two cached traces: the verify window and the one-token pool
    # decode — occupancy changes never recompile either
    assert eng.spec_traces == 1
    assert eng.batched_traces == 1
    assert (eng.spec_dispatches + eng.spec_draftless_rounds
            == s1.metrics["decode_calls"])
    eng.allocator.check()


def test_spec_disabled_by_default(gt):
    cfg, model, params = gt
    eng = RealEngine(cfg, model, params, max_len=64)
    assert not eng.spec and not Scheduler(eng).spec
    # recurrent families can never speculate (no per-position KV)
    xcfg = dataclasses.replace(base.get_config("xlstm-1.3b").reduced(),
                               spec_enabled=True, spec_k=4)
    xmodel = build_model(xcfg)
    xeng = RealEngine(xcfg, xmodel, xmodel.init(jax.random.PRNGKey(1)),
                      max_len=64)
    assert not xeng.spec


def test_verify_window_respects_max_len_page_bounds(gt):
    """Rows parked near max_len must clamp their draft window instead of
    indexing past the page table (scratch-masked pad tokens)."""
    eng = _spec_engine(gt, max_len=48)           # short ceiling, spec_k=4
    s = Scheduler(eng, max_active=2)
    s.submit(Request(0, [4, 6] * 16, max_new=40))  # 32 prompt + decode to cap
    done = s.run()
    assert done and done[0].output
    # pos never crossed the ceiling and the allocator stayed consistent
    assert all(len(r.output) <= 40 for r in done)
    eng.allocator.check()


# ------------------------------------------------------------ accounting
def test_spec_counters_and_accept_rate(gt):
    eng = _spec_engine(gt, max_len=128)
    assert eng.spec_accept_rate == 0.0
    s = Scheduler(eng, max_active=2)
    s.submit(Request(0, [5, 9, 2, 7] * 10, max_new=24))
    s.run()
    assert eng.spec_dispatches > 0
    assert eng.spec_drafted >= eng.spec_accepted > 0
    assert 0.0 < eng.spec_accept_rate <= 1.0
    # committed-token accounting: every round commits >= 1 token
    assert eng.spec_tokens >= eng.spec_dispatches


# ------------------------------------------------------------ overlay sync
def test_model_node_reports_accept_rate(gt):
    """The HR-tree sync broadcast carries the engine's speculative accept
    rate alongside kv_pressure, and peers record it."""
    from repro.net import messages
    from repro.overlay.model_node import ModelNode

    eng = _spec_engine(gt, max_len=128)
    s = Scheduler(eng, max_active=2)
    s.submit(Request(0, [5, 9, 2, 7] * 10, max_new=24))
    s.run()
    node = ModelNode("m0", use_crypto=False, real_engine=eng)
    rate = node._spec_accept_rate()
    assert rate == pytest.approx(eng.spec_accept_rate) and rate > 0.0

    msg = {"type": "hr_sync", "from": "m0", "paths": [], "active": 1,
           "hw": 5.0, "spec_accept_rate": rate}
    assert messages.validate(msg)
    peer = ModelNode("m1", use_crypto=False)
    peer._handle_sync(None, msg)
    assert peer.peers["m0"].spec_accept_rate == pytest.approx(rate)
    assert peer._spec_accept_rate() == 0.0       # latency-model node
