"""Slot-pool continuous batching: parity with the sequential engine,
single-dispatch/single-trace guarantees, and exact prefix-cache accounting.

Deliberately hypothesis-free so it runs even without dev extras installed.
"""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base
from repro.models.lm import build_model, cache_slot_read, cache_slot_write
from repro.serving.engine import RealEngine, Request
from repro.serving.prefix_cache import PrefixCache
from repro.serving.scheduler import Scheduler


@pytest.fixture(scope="module")
def gt():
    cfg = base.get_config("gentorrent-llama3-8b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompts(cfg, n, lengths=(20, 40, 36, 20, 44)):
    return [[(37 * i + j) % cfg.vocab for j in range(lengths[i % len(lengths)])]
            for i in range(n)]


# ------------------------------------------------------------- slot helpers
def test_cache_slot_write_read_roundtrip(gt):
    cfg, model, _ = gt
    pool = model.cache_zeros(3, 32)
    single = jax.tree.map(
        lambda a: jnp.full(a.shape[:1] + (1,) + a.shape[2:], 2.0, a.dtype),
        model.cache_zeros(1, 32))
    pool2 = cache_slot_write(pool, single, 1)
    got = cache_slot_read(pool2, 1)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(single)):
        assert a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # other rows untouched
    for a, b in zip(jax.tree.leaves(cache_slot_read(pool2, 0)),
                    jax.tree.leaves(cache_slot_read(pool, 0))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------------------ parity
def test_batched_matches_sequential_attn(gt):
    cfg, model, params = gt
    prompts = _prompts(cfg, 6)
    eng_seq = RealEngine(cfg, model, params, max_len=128)
    ref = {i: eng_seq.generate(Request(i, p, max_new=8)).output
           for i, p in enumerate(prompts)}
    eng_b = RealEngine(cfg, model, params, max_len=128)
    s = Scheduler(eng_b, max_active=4)
    for i, p in enumerate(prompts):
        s.submit(Request(i, p, max_new=8))
    out = {r.req_id: r.output for r in s.run()}
    assert out == ref
    # occupancy varied over the run (6 reqs through 4 slots), yet the
    # batched decode compiled exactly once — dead slots are masked
    assert eng_b.batched_traces == 1


@pytest.mark.parametrize("arch", ["xlstm-1.3b", "jamba-v0.1-52b"])
def test_batched_matches_sequential_recurrent(arch):
    cfg = base.get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = [[(11 * i + j) % cfg.vocab for j in range(12)] for i in range(3)]
    eng_seq = RealEngine(cfg, model, params, max_len=64)
    ref = {i: eng_seq.generate(Request(i, p, max_new=5)).output
           for i, p in enumerate(prompts)}
    eng_b = RealEngine(cfg, model, params, max_len=64)
    s = Scheduler(eng_b, max_active=2)
    for i, p in enumerate(prompts):
        s.submit(Request(i, p, max_new=5))
    out = {r.req_id: r.output for r in s.run()}
    assert out == ref
    assert eng_b.batched_traces == 1


def test_midstream_admission_into_partial_batch(gt):
    cfg, model, params = gt
    prompts = _prompts(cfg, 4)
    eng_seq = RealEngine(cfg, model, params, max_len=128)
    ref = {i: eng_seq.generate(Request(i, p, max_new=10)).output
           for i, p in enumerate(prompts)}
    eng_b = RealEngine(cfg, model, params, max_len=128)
    s = Scheduler(eng_b, max_active=3)
    s.submit(Request(0, prompts[0], max_new=10))
    s.submit(Request(1, prompts[1], max_new=10))
    for _ in range(3):
        s.step()           # two slots mid-decode, one free
    assert len(s.active) == 2
    s.submit(Request(2, prompts[2], max_new=10))
    s.submit(Request(3, prompts[3], max_new=10))
    out = {r.req_id: r.output for r in s.run()}
    assert out == ref
    assert eng_b.batched_traces == 1


# ---------------------------------------------------------- dispatch count
def test_step_issues_exactly_one_decode_dispatch(gt):
    cfg, model, params = gt
    eng = RealEngine(cfg, model, params, max_len=128)
    s = Scheduler(eng, max_active=3)
    for i in range(3):
        s.submit(Request(i, [7] * 12 + [i], max_new=6, eos_id=-1))
    s.step()               # admissions + first batched round
    assert len(s.active) == 3

    batched_calls = []
    real_batched = eng._decode_batched
    eng._decode_batched = lambda *a: (batched_calls.append(1)
                                      or real_batched(*a))

    def _no_single(*a):    # pragma: no cover - failure path
        raise AssertionError("per-request decode dispatched from step()")
    eng._decode = _no_single

    while s.active:
        n0 = len(batched_calls)
        s.step()
        made = len(batched_calls) - n0
        # exactly one pool dispatch whenever any slot survives the round,
        # zero when the round retires every remaining slot
        assert made == (1 if s.active else 0)
    assert s.metrics["completed"] == 3
    assert eng.batched_traces == 1


def test_scheduler_admission_scan_uses_peek(gt):
    """Ranking queued requests must not skew cache stats or LRU order."""
    cfg, model, params = gt
    eng = RealEngine(cfg, model, params, max_len=128)
    warm = [3] * 40
    eng.generate(Request(0, warm + [1], max_new=4))
    h0, m0 = eng.prefix_cache.hits, eng.prefix_cache.misses
    s = Scheduler(eng, max_active=1)
    for i in range(4):
        s.submit(Request(10 + i, warm + [10 + i], max_new=2))
    s.run()
    # one real match per admission (4 total); the 4x4-ish ranking probes of
    # the queue must not have touched the counters
    assert (eng.prefix_cache.hits - h0) + (eng.prefix_cache.misses - m0) == 4


def test_finished_slot_cache_covers_only_decoded_tokens(gt):
    """A finished request's last token is appended but never decoded, so
    the inserted prefix-cache entry must not claim coverage of its
    position — a later request reusing that block would attend zero KV."""
    cfg, model, params = gt
    prompt = [11] * 16
    first = RealEngine(cfg, model, params, max_len=128).generate(
        Request(0, prompt, max_new=48)).output     # full stream: 64 = 2 blocks
    follow = prompt + first
    ref = RealEngine(cfg, model, params, max_len=128).generate(
        Request(1, follow, max_new=4)).output      # cache-free reference

    eng = RealEngine(cfg, model, params, max_len=128)
    s = Scheduler(eng, max_active=2)
    s.submit(Request(0, prompt, max_new=48))
    assert s.run()[0].output == first
    s.submit(Request(1, follow, max_new=4))
    out = {r.req_id: r.output for r in s.run()}[1]
    assert out == ref


def test_max_new_zero_matches_sequential(gt):
    cfg, model, params = gt
    eng = RealEngine(cfg, model, params, max_len=128)
    assert eng.generate(Request(0, [6] * 12, max_new=0)).output == []
    s = Scheduler(eng, max_active=2)
    s.submit(Request(1, [5] * 12, max_new=0))
    done = s.run()
    assert done and done[0].output == []


# ------------------------------------------------------------ overlay e2e
def test_overlay_real_engine_uses_batched_scheduler(gt):
    """ModelNode's real_engine path must serve through the slot pool."""
    from repro.overlay.network import OverlayConfig, build_overlay
    cfg, model, params = gt
    prompt = [5] * 20
    ref = RealEngine(cfg, model, params, max_len=128).generate(
        Request(0, prompt, max_new=4)).output
    ov = build_overlay(OverlayConfig(n_users=8, n_models=2,
                                     use_crypto=False, seed=5))
    eng = RealEngine(cfg, model, params, max_len=128)
    for m in ov.models:
        m.real_engine = eng
    got = []
    u = ov.users[0]
    u.on_response = lambda _n, p: got.append(p)
    u.send_prompt(ov.net, prompt, extra_meta={"max_new": 4})
    ov.net.run_until(ov.net.t + 60)
    assert got and got[0]["output"] == ref
    served = [m for m in ov.models if m._real_sched is not None]
    assert served
    assert sum(m._real_sched.metrics["decode_calls"] for m in served) > 0
    assert sum(m._real_sched.metrics["completed"] for m in served) == 1


# ------------------------------------------------------ prefix-cache bytes
def _live_bytes(pc: PrefixCache) -> int:
    return sum(e.nbytes for e in
               {id(e): e for e in pc._by_chain.values()}.values())


def test_used_bytes_released_when_entry_loses_all_keys():
    pc = PrefixCache(block=8)
    toks = list(range(32))
    pc.insert(toks, "A", 100)
    pc.insert(toks + list(range(32, 48)), "B", 150)   # re-keys all of A
    assert pc.used_bytes == _live_bytes(pc) == 150
    pc.insert(toks[:8] + [99] * 8, "C", 50)           # B keeps deeper keys
    assert pc.used_bytes == _live_bytes(pc) == 200


def test_used_bytes_exact_under_random_churn():
    random.seed(7)
    pc = PrefixCache(max_bytes=20_000, block=8)
    streams = []
    for _ in range(600):
        if streams and random.random() < 0.6:
            seed = random.choice(streams)
            cut = random.randrange(0, len(seed) + 1, 8)
            toks = seed[:cut] + [random.randrange(50)
                                 for _ in range(random.randrange(0, 40))]
        else:
            toks = [random.randrange(50)
                    for _ in range(random.randrange(8, 80))]
        streams.append(toks)
        streams = streams[-40:]
        pc.insert(toks, None, random.randrange(1, 500))
        assert pc.used_bytes == _live_bytes(pc)
        assert pc.used_bytes <= pc.max_bytes


def test_peek_is_read_only():
    pc = PrefixCache(block=8)
    toks = list(range(32))
    pc.insert(toks, "A", 10)
    e = pc._by_chain[list(pc._by_chain)[0]]
    before = (pc.hits, pc.misses, pc.hit_tokens, e.hits, e.last_used)
    ln, got = pc.peek(toks)
    assert ln == 32 and got is not None
    ln2, got2 = pc.peek([999] * 32)
    assert ln2 == 0 and got2 is None
    assert (pc.hits, pc.misses, pc.hit_tokens, e.hits, e.last_used) == before
    # match() still counts
    pc.match(toks)
    assert pc.hits == 1
