"""Token-level PPL verification with a real (tiny, trained) JAX model:
the GT model's own responses must score higher credibility than a
degraded impostor's — the mechanism behind Fig 11/12."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base
from repro.core.verification import VerifierModel, avg_credibility, \
    credibility
from repro.models.lm import build_model
from repro.training import optimizer as opt_lib
from repro.training.data import MarkovCorpus
from repro.training.train_step import make_train_step


@pytest.fixture(scope="module")
def trained():
    cfg = base.get_config("gentorrent-llama3-8b").reduced()
    cfg = dataclasses.replace(cfg, vocab=256)
    model = build_model(cfg)
    adamw = opt_lib.AdamWConfig(lr=5e-3, warmup_steps=3, total_steps=60)
    step = jax.jit(make_train_step(cfg, model, adamw, block_q=32))
    params = model.init(jax.random.PRNGKey(0))
    opt = opt_lib.init_state(params)
    corpus = MarkovCorpus(cfg.vocab, seed=0)
    for b in corpus.batches(8, 48, 60):
        params, opt, m = step(params, opt,
                              {k: jnp.asarray(v) for k, v in b.items()})
    return cfg, model, params, corpus, float(m["loss"])


def _greedy(model, params, prompt, n=12):
    toks = list(prompt)
    logits, cache = jax.jit(
        lambda p, t: model.prefill(p, t, max_len=len(prompt) + n + 2,
                                   block_q=16))(params,
                                                jnp.asarray([toks], jnp.int32))
    out = []
    pos = len(toks)
    dec = jax.jit(model.decode)
    for _ in range(n):
        nxt = int(jnp.argmax(logits[0]))
        out.append(nxt)
        logits, cache = dec(params, cache, jnp.asarray([[nxt]], jnp.int32),
                            jnp.asarray([pos], jnp.int32))
        pos += 1
    return out


def _quantize_params(params, levels=8):
    def q(x):
        if x.ndim < 2:
            return x
        s = jnp.max(jnp.abs(x)) + 1e-9
        return jnp.round(x / s * levels) / levels * s
    return jax.tree.map(q, params)


def test_gt_scores_higher_than_impostors(trained):
    cfg, model, params, corpus, final_loss = trained
    assert final_loss < 5.0  # learned something
    verifier = VerifierModel(cfg, model, params)
    impostor_rand = build_model(cfg).init(jax.random.PRNGKey(9))
    impostor_q = _quantize_params(params, levels=3)  # brutal quantization

    gt_scores, rand_scores, q_scores = [], [], []
    rng = np.random.default_rng(0)
    for i in range(6):
        prompt = corpus.sample(1, 16, rng)[0, :16].tolist()
        gt_resp = _greedy(model, params, prompt)
        rand_resp = _greedy(model, impostor_rand, prompt)
        q_resp = _greedy(model, impostor_q, prompt)
        gt_scores.append(credibility(verifier, prompt, gt_resp))
        rand_scores.append(credibility(verifier, prompt, rand_resp))
        q_scores.append(credibility(verifier, prompt, q_resp))

    assert np.mean(gt_scores) > np.mean(rand_scores), \
        (gt_scores, rand_scores)
    assert np.mean(gt_scores) > np.mean(q_scores), (gt_scores, q_scores)


def test_avg_credibility_empty():
    assert avg_credibility(None, []) == 0.0
