"""Pallas kernel validation: interpret-mode vs pure-jnp oracles across
shape/dtype sweeps (the per-kernel allclose deliverable)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.chunk_hash.ops import chunk_hash_fixed
from repro.kernels.chunk_hash.ref import chunk_hash_ref
from repro.kernels.decode_attention.ops import (decode_attention,
                                                paged_decode_attention)
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.mamba_scan.ops import mamba_scan
from repro.kernels.mlstm.ops import mlstm_chunkwise

RNG = jax.random.PRNGKey(0)


@pytest.mark.parametrize("B,H,Hkv,S,D,causal,window,softcap,dtype", [
    (2, 4, 2, 256, 64, True, None, None, jnp.float32),
    (1, 4, 4, 256, 64, True, 64, None, jnp.float32),
    (2, 8, 2, 128, 64, True, None, 30.0, jnp.float32),
    (1, 2, 1, 256, 128, False, None, None, jnp.float32),
    (1, 4, 2, 128, 64, True, None, None, jnp.bfloat16),
])
def test_flash_attention(B, H, Hkv, S, D, causal, window, softcap, dtype):
    ks = jax.random.split(jax.random.fold_in(RNG, S + H + D), 3)
    q = jax.random.normal(ks[0], (B, H, S, D), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, S, D), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, S, D), dtype)
    ref = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, impl="ref")
    pal = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, impl="interpret", bq=64, bk=64)
    tol = 2e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(pal, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("B,H,Hkv,S,D,window,softcap,ns", [
    (2, 4, 2, 256, 64, None, None, 4),
    (2, 4, 1, 512, 64, None, None, 8),
    (1, 8, 8, 256, 128, 128, None, 5),
    (2, 2, 2, 256, 64, None, 50.0, 1),
])
def test_decode_attention(B, H, Hkv, S, D, window, softcap, ns):
    ks = jax.random.split(jax.random.fold_in(RNG, S + H + ns), 4)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Hkv, S, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Hkv, S, D), jnp.float32)
    lengths = jax.random.randint(ks[3], (B,), S // 4, S + 1)
    ref = decode_attention(q, k, v, lengths, window=window, softcap=softcap,
                           impl="ref")
    pal = decode_attention(q, k, v, lengths, window=window, softcap=softcap,
                           n_splits=ns, impl="interpret")
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("B,H,Hkv,D,blk,P,n_pg,window,softcap", [
    (2, 4, 2, 64, 32, 12, 4, None, None),
    (3, 4, 1, 64, 16, 9, 6, None, None),
    (1, 8, 8, 128, 32, 6, 3, 48, None),
    (2, 2, 2, 64, 32, 8, 4, None, 50.0),
])
def test_paged_decode_attention(B, H, Hkv, D, blk, P, n_pg, window,
                                softcap):
    """In-kernel page-table gather (scalar prefetch) vs the gather-then-
    dense oracle, including a zero-length (masked slot-pool) row."""
    ks = jax.random.split(jax.random.fold_in(RNG, P + n_pg + H), 4)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
    ka = jax.random.normal(ks[1], (P, blk, Hkv, D), jnp.float32)
    va = jax.random.normal(ks[2], (P, blk, Hkv, D), jnp.float32)
    pt = jax.random.randint(ks[3], (B, n_pg), 1, P)      # 0 = scratch page
    lengths = jnp.asarray([n_pg * blk - 3] + [0] * (B - 1), jnp.int32)
    ref = paged_decode_attention(q, ka, va, pt, lengths, window=window,
                                 softcap=softcap, impl="ref")
    pal = paged_decode_attention(q, ka, va, pt, lengths, window=window,
                                 softcap=softcap, impl="interpret")
    # rows with length 0 are fully masked garbage by contract (discarded
    # by the slot-pool caller) — compare only the live row
    np.testing.assert_allclose(np.asarray(pal[:1]), np.asarray(ref[:1]),
                               rtol=2e-4, atol=2e-4)


def test_paged_decode_matches_dense_decode():
    """Sequentially paged KV (identity page table) must reproduce the
    dense split-K kernel exactly — paging is layout, not math."""
    ks = jax.random.split(jax.random.fold_in(RNG, 77), 4)
    B, H, Hkv, D, blk, n_pg = 2, 4, 2, 64, 32, 4
    S = blk * n_pg
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Hkv, S, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Hkv, S, D), jnp.float32)
    lengths = jnp.asarray([S, S // 2 + 5], jnp.int32)
    dense = decode_attention(q, k, v, lengths, impl="ref")
    # lay request b's KV out as pages [b*n_pg .. b*n_pg+n_pg)
    ka = k.transpose(0, 2, 1, 3).reshape(B * n_pg, blk, Hkv, D)
    va = v.transpose(0, 2, 1, 3).reshape(B * n_pg, blk, Hkv, D)
    pt = jnp.arange(B * n_pg, dtype=jnp.int32).reshape(B, n_pg)
    paged = paged_decode_attention(q, ka, va, pt, lengths,
                                   impl="interpret")
    np.testing.assert_allclose(np.asarray(paged), np.asarray(dense),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("B,H,C,L,P,N", [
    (2, 2, 4, 16, 32, 8),
    (1, 4, 3, 32, 64, 16),
])
def test_mamba_scan(B, H, C, L, P, N):
    ks = jax.random.split(jax.random.fold_in(RNG, C * L + P), 5)
    xbar = jax.random.normal(ks[0], (B, H, C, L, P), jnp.float32) * 0.5
    loga = -jax.nn.softplus(jax.random.normal(ks[1], (B, H, C, L)))
    Bm = jax.random.normal(ks[2], (B, C, L, N), jnp.float32) * 0.5
    Cm = jax.random.normal(ks[3], (B, C, L, N), jnp.float32) * 0.5
    h0 = jax.random.normal(ks[4], (B, H, N, P), jnp.float32) * 0.1
    y_r, h_r = mamba_scan(xbar, loga, Bm, Cm, h0, impl="ref")
    y_p, h_p = mamba_scan(xbar, loga, Bm, Cm, h0, impl="interpret")
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_p), np.asarray(h_r),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("B,H,C,L,dh", [
    (2, 2, 4, 16, 32),
    (1, 4, 2, 32, 64),
])
def test_mlstm_chunkwise(B, H, C, L, dh):
    ks = jax.random.split(jax.random.fold_in(RNG, C * L + dh), 5)
    q = jax.random.normal(ks[0], (B, H, C, L, dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, H, C, L, dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, H, C, L, dh), jnp.float32)
    gi = jax.random.normal(ks[3], (B, H, C, L)) * 2.0
    gf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, H, C, L)) + 4.0)
    h_r, (C_r, n_r, m_r) = mlstm_chunkwise(q, k, v, gi, gf, impl="ref",
                                           scale=0.17)
    h_p, (C_p, n_p, m_p) = mlstm_chunkwise(q, k, v, gi, gf,
                                           impl="interpret", scale=0.17)
    np.testing.assert_allclose(np.asarray(h_p), np.asarray(h_r),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(C_p), np.asarray(C_r),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(m_p), np.asarray(m_r),
                               rtol=1e-5, atol=1e-5)


def test_mlstm_state_continuation():
    B, H, C, L, dh = 1, 2, 2, 16, 32
    ks = jax.random.split(RNG, 5)
    q = jax.random.normal(ks[0], (B, H, 2 * C, L, dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, H, 2 * C, L, dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, H, 2 * C, L, dh), jnp.float32)
    gi = jax.random.normal(ks[3], (B, H, 2 * C, L)) * 2.0
    gf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, H, 2 * C, L)) + 4.0)
    h_full, _ = mlstm_chunkwise(q, k, v, gi, gf, impl="interpret")
    h1, st1 = mlstm_chunkwise(q[:, :, :C], k[:, :, :C], v[:, :, :C],
                              gi[:, :, :C], gf[:, :, :C], impl="interpret")
    h2, _ = mlstm_chunkwise(q[:, :, C:], k[:, :, C:], v[:, :, C:],
                            gi[:, :, C:], gf[:, :, C:], state0=st1,
                            impl="interpret")
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full[:, :, C:]),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("width", [16, 64, 128])
def test_chunk_hash_matches_hrtree(width):
    toks = np.random.default_rng(0).integers(
        0, 50_000, (3, 512)).astype(np.int32)
    hp = np.asarray(chunk_hash_fixed(jnp.asarray(toks), width=width, bits=8,
                                     impl="interpret"))
    hr = chunk_hash_ref(toks, width=width, bits=8)
    assert np.array_equal(hp, hr)
