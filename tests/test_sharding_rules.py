"""Sharding rules must cover every parameter/cache leaf of every assigned
architecture with rank-correct, divisibility-safe PartitionSpecs."""
import jax
import pytest
from jax.sharding import AbstractMesh

from repro.configs import base
from repro.distributed import sharding
from repro.models.lm import build_model

MESH = AbstractMesh((("data", 16), ("model", 16)))
MESH_MP = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))


@pytest.mark.parametrize("arch", base.ASSIGNED)
@pytest.mark.parametrize("mesh", [MESH, MESH_MP], ids=["1pod", "2pod"])
@pytest.mark.parametrize("train", [True, False], ids=["train", "serve"])
def test_param_rules_cover_all_leaves(arch, mesh, train):
    cfg = base.get_config(arch)
    model = build_model(cfg)
    specs = model.param_specs()

    def check(path, leaf):
        spec = sharding.param_pspec(cfg, path, leaf.shape, mesh, train)
        assert len(spec) <= len(leaf.shape)
        # divisibility: every sharded dim divides
        for dim, axes in zip(leaf.shape, tuple(spec) + (None,) * 10):
            if axes is None:
                continue
            for ax in ([axes] if isinstance(axes, str) else axes):
                assert dim % mesh.shape[ax] == 0, (path, leaf.shape, spec)
        return spec

    jax.tree_util.tree_map_with_path(check, specs)


@pytest.mark.parametrize("arch", ["h2o-danube-1.8b", "jamba-v0.1-52b",
                                  "xlstm-1.3b", "gemma2-9b", "whisper-base"])
def test_cache_rules_cover_all_leaves(arch):
    cfg = base.get_config(arch)
    model = build_model(cfg)
    shape = base.SHAPES["decode_32k"]
    T_mem = shape.seq_len // 2 if cfg.is_encdec else cfg.n_image_tokens
    specs = model.cache_specs(shape.global_batch, shape.seq_len, T_mem)

    def check(path, leaf):
        for long_ctx in (False, True):
            spec = sharding.cache_pspec(cfg, path, leaf.shape, MESH, long_ctx)
            assert len(spec) <= len(leaf.shape), (path, leaf.shape, spec)
            for dim, axes in zip(leaf.shape, tuple(spec) + (None,) * 10):
                if axes is None:
                    continue
                for ax in ([axes] if isinstance(axes, str) else axes):
                    assert dim % MESH.shape[ax] == 0, (path, leaf.shape, spec)

    jax.tree_util.tree_map_with_path(check, specs)


def test_kv_axis_divisibility_policy():
    gemma = base.get_config("gemma2-9b")      # kv=8 < 16 -> None
    assert sharding.kv_axis(gemma, MESH) is None
    moonshot = base.get_config("moonshot-v1-16b-a3b")  # kv=16 -> model
    assert sharding.kv_axis(moonshot, MESH) == "model"


def test_batch_axes():
    assert sharding.batch_axes(MESH, 256) == ("data",)
    assert sharding.batch_axes(MESH_MP, 256) == ("pod", "data")
    assert sharding.batch_axes(MESH, 1) == ()
