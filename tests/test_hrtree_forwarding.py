"""HR-tree, Sentry, and forwarding-logic tests (+ hypothesis invariants)."""
import random

# hypothesis-optional: only the property test below needs it — the
# deterministic HR-tree / sentry / decide() coverage must still run on a
# bare interpreter (tests/conftest.py collects this module either way)
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import hrtree, sentry
from repro.core.forwarding import (ForwardingConfig, PeerInfo,
                                   PrefixSketch, decide)
from repro.serving.prefix_cache import _chain_hashes


def make_tree(lengths=(32,), default_chunk=16):
    return hrtree.HRTree(lengths, bits=8, default_chunk=default_chunk)


def test_insert_then_search_finds_holder():
    t = make_tree()
    toks = list(range(128))
    t.insert_tokens(toks, "A")
    holders, d = t.search_tokens(toks, tau=2)
    assert "A" in holders and d >= 2


def test_prefix_semantics():
    t = make_tree()
    shared = list(range(64))
    t.insert_tokens(shared + [500] * 32, "A")
    # query sharing only the 64-token prefix still matches at partial depth
    holders, d = t.search_tokens(shared + [900] * 32, tau=1)
    assert "A" in holders
    # totally different prompt: no match
    holders, d = t.search_tokens([7] * 128, tau=1)
    assert holders == []


def test_export_merge_roundtrip():
    t = make_tree()
    toks = list(range(96))
    t.insert_tokens(toks, "A")
    paths = t.export_paths("A")
    t2 = make_tree()
    t2.merge_paths(paths, "A")
    h1, d1 = t.search_tokens(toks, tau=1)
    h2, d2 = t2.search_tokens(toks, tau=1)
    assert h1 == h2 and d1 == d2


def test_remove_holder_and_expire():
    t = make_tree()
    t.insert_tokens(list(range(64)), "A", ts=1.0)
    t.insert_tokens(list(range(64)), "B", ts=5.0)
    t.remove_holder("A")
    holders, _ = t.search_tokens(list(range(64)), tau=1)
    assert holders == ["B"]
    t.expire(before_ts=10.0)
    holders, _ = t.search_tokens(list(range(64)), tau=1)
    assert holders == []


def test_false_positive_rate_math():
    t = make_tree()
    assert t.false_positive_rate(3) == (1 / 256) ** 3


if HAVE_HYPOTHESIS:
    @given(st.lists(st.integers(0, 1000), min_size=16, max_size=200),
           st.integers(0, 3))
    @settings(max_examples=30, deadline=None)
    def test_hrtree_inserted_always_found(tokens, tau):
        t = make_tree()
        t.insert_tokens(tokens, "X")
        n_hashes = len(hrtree.preprocess(tokens, t.lengths, t.bits,
                                         t.default_chunk))
        holders, d = t.search_tokens(tokens, tau=tau)
        assert d == n_hashes
        if d >= tau:
            assert "X" in holders


# ---------------------------------------------------------------- Sentry
def test_sentry_length_equations():
    assert sentry.build_lengths([32, 64, 128], 8) == [32, 8, 24, 8, 56]
    assert sentry.build_lengths([], 8) == []
    assert sentry.build_lengths([16], 4) == [16]


def test_sentry_detects_common_prefix():
    s = sentry.Sentry(sentry.SentryConfig(min_support=5, min_len=16,
                                          probe_stride=16))
    common = tuple(range(48))
    rng = random.Random(0)
    for i in range(40):
        tail = tuple(rng.randrange(2000, 3000) for _ in range(40))
        s.observe(common + tail)
    lengths = s.detect_prompt_lengths()
    assert lengths and max(lengths) >= 32  # found the shared prefix


# ---------------------------------------------------------------- Forwarding
def _tree_with(holder, tokens):
    t = make_tree()
    t.insert_tokens(tokens, holder)
    return t


def test_forward_match_prefers_cache_holder():
    toks = list(range(128))
    t = _tree_with("A", toks)
    peers = {"A": PeerInfo("A", 5, 3), "B": PeerInfo("B", 5, 0)}
    d = decide(ForwardingConfig(load_threshold=4.0), t, peers, toks)
    assert d.reason == "cache_hit" and d.target == "A"


def test_forward_overloaded_holder_falls_back():
    toks = list(range(128))
    t = _tree_with("A", toks)
    peers = {"A": PeerInfo("A", 5, 100), "B": PeerInfo("B", 5, 1)}
    d = decide(ForwardingConfig(load_threshold=4.0), t, peers, toks)
    assert d.reason == "load_balance" and d.target == "B"


def test_forward_relative_load_respects_hw_score():
    toks = [9] * 64  # miss
    t = make_tree()
    # A: 10 active on hw 10 (rel 1.0); B: 2 active on hw 1 (rel 2.0)
    peers = {"A": PeerInfo("A", 10, 10), "B": PeerInfo("B", 1, 2)}
    d = decide(ForwardingConfig(), t, peers, toks)
    assert d.target == "A"


def test_forward_tiebreak_spreads():
    t = make_tree()
    peers = {f"n{i}": PeerInfo(f"n{i}", 5, 0) for i in range(4)}
    targets = {decide(ForwardingConfig(), t, peers,
                      [seed] * 40).target for seed in range(40)}
    assert len(targets) >= 3


# --------------------------------------------------- prefix-affinity sketch
def _sketch_of(tokens) -> bytes:
    """What a node caching ``tokens`` broadcasts: a bloom over the chain
    digest of every block depth (prefix_cache registers all of them)."""
    return PrefixSketch.build(_chain_hashes(tokens)).to_bytes()


def test_sketch_roundtrip_and_hit_depth():
    toks = list(range(96))                        # 3 blocks
    digests = _chain_hashes(toks)
    sk = PrefixSketch.from_bytes(_sketch_of(toks))
    assert sk.hit_depth(digests) == 3
    # a stream sharing only the first 2 blocks matches at depth 2
    sibling = toks[:64] + [999] * 32
    assert sk.hit_depth(_chain_hashes(sibling)) == 2
    # an unrelated stream misses at depth 0 (no false positive here)
    assert sk.hit_depth(_chain_hashes([5000 + i for i in range(96)])) == 0


def test_affinity_routes_to_deepest_sketch_hit():
    toks = list(range(96)) + [7] * 8
    t = make_tree()                               # HR-tree knows nothing
    peers = {"A": PeerInfo("A", 5, 1, prefix_sketch=_sketch_of(toks[:32])),
             "B": PeerInfo("B", 5, 0, prefix_sketch=_sketch_of(toks[:96])),
             "C": PeerInfo("C", 5, 0)}            # no sketch yet
    d = decide(ForwardingConfig(), t, peers, toks)
    assert d.reason == "affinity" and d.target == "B" and d.depth == 3


def test_affinity_miss_falls_back_to_load_routing():
    """A sketch that only covers OTHER prompts (the false-positive probe:
    every peer broadcasts a sketch, none contains this prefix) must leave
    the decision exactly where the load-only path would put it."""
    toks = [9] * 64
    t = make_tree()
    sk_other = _sketch_of(list(range(2000, 2096)))
    peers = {"A": PeerInfo("A", 5, 3, prefix_sketch=sk_other),
             "B": PeerInfo("B", 5, 0, prefix_sketch=sk_other)}
    d = decide(ForwardingConfig(), t, peers, toks)
    ref = decide(ForwardingConfig(affinity=False), t, peers, toks)
    assert d.reason == "load_balance"
    assert (d.target, d.reason) == (ref.target, ref.reason)


def test_affinity_saturated_sketch_vetoed_by_load():
    """Worst-case bloom false positive — a saturated sketch 'hits' every
    prefix — must still be subject to the load veto: an overloaded
    claimant never captures the traffic itself.  With replication it is
    named as a fetch source instead (a false positive there only costs a
    refused kv_fetch); with replication off, the legacy load-balance
    fallback is byte-identical."""
    toks = list(range(64))
    t = make_tree()
    saturated = b"\xff" * len(_sketch_of(toks))
    peers = {"A": PeerInfo("A", 5, 100, prefix_sketch=saturated),
             "B": PeerInfo("B", 5, 1)}
    d = decide(ForwardingConfig(load_threshold=4.0), t, peers, toks)
    assert d.reason == "replicate" and d.target == "B"
    assert d.fetch_from == "A"
    d = decide(ForwardingConfig(load_threshold=4.0, replicate=False),
               t, peers, toks)
    assert d.reason == "load_balance" and d.target == "B"


def test_kv_pressure_vetoes_affinity_hit():
    """A true sketch hit on a node whose paged arena is nearly full must
    not co-route the sibling there (it would evict the very prefix it
    came for) — instead the request goes to a peer with headroom carrying
    a fetch hint naming the pressured holder."""
    toks = list(range(64)) + [3] * 8
    t = make_tree()
    holder = PeerInfo("A", 5, 0, prefix_sketch=_sketch_of(toks[:64]),
                      kv_pressure=0.95)
    other = PeerInfo("B", 5, 0)
    cfg = ForwardingConfig(kv_pressure_max=0.85)
    d = decide(cfg, t, {"A": holder, "B": other}, toks)
    assert d.reason == "replicate" and d.target == "B"
    assert d.fetch_from == "A" and d.depth == 2
    cfg_off = ForwardingConfig(kv_pressure_max=0.85, replicate=False)
    d = decide(cfg_off, t, {"A": holder, "B": other}, toks)
    assert d.reason == "load_balance" and d.target == "B"
    # drop the pressure below the threshold: the hit is honored again
    holder.kv_pressure = 0.5
    d = decide(cfg, t, {"A": holder, "B": other}, toks)
    assert d.reason == "affinity" and d.target == "A"


def test_replicate_min_blocks_gate():
    """A vetoed hit shallower than ``replicate_min_blocks`` re-prefills
    (shipping one block costs more than recomputing it) — and a depth-2
    hit replicates under the default gate."""
    t = make_tree()
    shallow = list(range(32)) + [9] * 8               # 1 block cached
    holder = PeerInfo("A", 5, 0, prefix_sketch=_sketch_of(shallow[:32]),
                      kv_pressure=0.95)
    other = PeerInfo("B", 5, 0)
    cfg = ForwardingConfig()
    d = decide(cfg, t, {"A": holder, "B": other}, shallow)
    assert d.reason == "load_balance"
    deep = list(range(64)) + [9] * 8                  # 2 blocks cached
    holder.prefix_sketch = _sketch_of(deep[:64])
    d = decide(cfg, t, {"A": holder, "B": other}, deep)
    assert d.reason == "replicate" and d.depth == 2


def test_replicate_needs_an_eligible_target():
    """When every non-holder peer is itself vetoed (pressure/load), there
    is nowhere to host the pages — the decision degrades to the legacy
    load-balance fallback instead of bouncing pages into a full arena."""
    toks = list(range(64)) + [1] * 8
    t = make_tree()
    holder = PeerInfo("A", 5, 0, prefix_sketch=_sketch_of(toks[:64]),
                      kv_pressure=0.95)
    full_b = PeerInfo("B", 5, 0, kv_pressure=0.99)
    d = decide(ForwardingConfig(), t, {"A": holder, "B": full_b}, toks)
    assert d.reason == "load_balance" and d.fetch_from is None


def test_decide_deterministic_across_peer_orderings():
    """The same peer state must yield the same target regardless of dict
    insertion order — min() over an order-dependent iteration would
    otherwise flap between equal-load peers."""
    toks = list(range(64))
    sk = _sketch_of(toks)
    t = make_tree()

    def mk(order):
        peers = {}
        for nid in order:
            peers[nid] = PeerInfo(nid, 5, 0, prefix_sketch=sk)
        return peers

    cfg = ForwardingConfig()
    for seed in range(20):
        q = [seed] * 48
        fwd = decide(cfg, t, mk(["A", "B", "C"]), q)
        rev = decide(cfg, t, mk(["C", "B", "A"]), q)
        assert (fwd.target, fwd.reason) == (rev.target, rev.reason)


def test_evicted_prefix_stops_attracting_affinity_after_sync():
    """Sketch freshness (double-buffered bloom): once the holder evicts a
    prefix, the sketch from its NEXT hr_sync must no longer attract
    sibling requests — stale bits may only persist until that sync."""
    from repro.serving.prefix_cache import PrefixCache

    toks = list(range(64))
    pc = PrefixCache()
    pc.insert(toks, None, 1024)
    t = make_tree()
    peers = {"A": PeerInfo("A", 5, 0, prefix_sketch=pc.sketch_bytes()),
             "B": PeerInfo("B", 5, 0)}
    d = decide(ForwardingConfig(), t, peers, toks + [9] * 8)
    assert d.reason == "affinity" and d.target == "A"

    assert pc.pop_lru()                   # eviction under pressure
    # pre-sync the stale broadcast still hits (point-in-time bloom) ...
    assert decide(ForwardingConfig(), t, peers,
                  toks + [9] * 8).reason == "affinity"
    # ... but the next sync's sketch has been rebuilt without the entry
    peers["A"].prefix_sketch = pc.sketch_bytes()
    d = decide(ForwardingConfig(), t, peers, toks + [9] * 8)
    assert d.reason != "affinity"


def test_sketch_incremental_insert_matches_rebuild():
    """The incrementally grown live buffer must broadcast the same bits a
    from-scratch rebuild would, across insert/evict interleavings."""
    from repro.serving.prefix_cache import PrefixCache

    pc = PrefixCache()
    streams = [list(range(s, s + 96)) for s in (0, 200, 400)]
    for toks in streams:
        pc.insert(toks, None, 64)
        assert pc.sketch_bytes() == \
            PrefixSketch.build(pc._by_chain.keys()).to_bytes()
    pc.pop_lru()
    assert pc.sketch_bytes() == \
        PrefixSketch.build(pc._by_chain.keys()).to_bytes()
    pc.insert(list(range(600, 664)), None, 64)   # insert after rebuild
    assert pc.sketch_bytes() == \
        PrefixSketch.build(pc._by_chain.keys()).to_bytes()


def test_affinity_disabled_preserves_legacy_paths():
    toks = list(range(128))
    t = _tree_with("A", toks)
    peers = {"A": PeerInfo("A", 5, 3, prefix_sketch=_sketch_of(toks)),
             "B": PeerInfo("B", 5, 0)}
    d = decide(ForwardingConfig(affinity=False), t, peers, toks)
    assert d.reason == "cache_hit" and d.target == "A"


# --------------------------------------------- accept-rate-aware routing
def test_accept_rate_breaks_load_ties_for_decode_heavy():
    """Equal-load peers: a decode-heavy request (n_out exceeds the
    prompt) goes to the higher speculative accept rate — its cost is
    verify dispatches, and that peer commits more tokens per dispatch."""
    toks = [4] * 16
    t = make_tree()
    peers = {"A": PeerInfo("A", 5, 2, spec_accept_rate=0.1),
             "B": PeerInfo("B", 5, 2, spec_accept_rate=0.8)}
    d = decide(ForwardingConfig(), t, peers, toks, n_out=64)
    assert d.reason == "load_balance" and d.target == "B"
    # prompt-heavy request: accept rate is ignored, the legacy
    # latency/tiebreak ordering decides
    ref = decide(ForwardingConfig(accept_rate_routing=False), t, peers,
                 toks, n_out=64)
    d = decide(ForwardingConfig(), t, peers, toks, n_out=4)
    assert (d.target, d.reason) == (ref.target, ref.reason)


def test_accept_rate_never_outvotes_load():
    """The accept rate only breaks TIES: a less-loaded low-accept peer
    still wins over a busier high-accept one."""
    toks = [4] * 8
    t = make_tree()
    peers = {"A": PeerInfo("A", 5, 1, spec_accept_rate=0.0),
             "B": PeerInfo("B", 5, 3, spec_accept_rate=0.9)}
    d = decide(ForwardingConfig(), t, peers, toks, n_out=64)
    assert d.target == "A"


def test_accept_rate_tie_is_deterministic():
    """Equal accept rates at equal load: the decision must match the
    accept-rate-blind path exactly and be stable across peer orderings
    (no flapping between syncs)."""
    t = make_tree()

    def mk(order, rate):
        peers = {}
        for nid in order:
            peers[nid] = PeerInfo(nid, 5, 1, spec_accept_rate=rate)
        return peers

    cfg = ForwardingConfig()
    blind = ForwardingConfig(accept_rate_routing=False)
    for seed in range(20):
        q = [seed] * 24
        fwd = decide(cfg, t, mk(["A", "B", "C"], 0.5), q, n_out=64)
        rev = decide(cfg, t, mk(["C", "B", "A"], 0.5), q, n_out=64)
        ref = decide(blind, t, mk(["A", "B", "C"], 0.5), q, n_out=64)
        assert (fwd.target, fwd.reason) == (rev.target, rev.reason)
        assert (fwd.target, fwd.reason) == (ref.target, ref.reason)


# --------------------------------------------------- sketch size ladder
def test_sketch_size_ladder():
    from repro.core.forwarding import (SKETCH_LADDER, sketch_size_for)
    assert sketch_size_for(0) == 64 and sketch_size_for(32) == 64
    assert sketch_size_for(33) == 128
    assert sketch_size_for(10_000) == SKETCH_LADDER[-1]
    # ladder must be monotone powers of two
    assert all(b == 2 * a for a, b in zip(SKETCH_LADDER, SKETCH_LADDER[1:]))


def test_sketch_scales_with_cache_size_and_interops():
    """A churny cache outgrows the 64-byte rung: the broadcast sketch
    steps up the ladder, ``from_bytes`` accepts the larger buffer, and
    hit depths stay exact for cached streams at every size."""
    from repro.serving.prefix_cache import PrefixCache

    pc = PrefixCache()
    streams = [list(range(s, s + 96)) for s in range(0, 2000, 100)]
    sizes = set()
    for toks in streams:
        pc.insert(toks, None, 64)
        raw = pc.sketch_bytes()
        sizes.add(len(raw))
        sk = PrefixSketch.from_bytes(raw)
        # every cached stream still hits at full depth through the wire
        assert sk.hit_depth(_chain_hashes(toks)) == 3
        # incremental growth stays equal to a from-scratch rebuild at
        # the same rung (the PR-4 invariant, now per ladder size)
        assert raw == PrefixSketch.build(pc._by_chain.keys()).to_bytes()
    assert len(sizes) > 1 and 64 in sizes            # it actually stepped
    # peers on different rungs interoperate inside one decide() call
    t = make_tree()
    small = PrefixSketch.build(_chain_hashes(streams[0])).to_bytes()
    peers = {"A": PeerInfo("A", 5, 0, prefix_sketch=small),
             "B": PeerInfo("B", 5, 0, prefix_sketch=pc.sketch_bytes())}
    d = decide(ForwardingConfig(), t, peers, streams[-1] + [7] * 8)
    assert d.reason == "affinity" and d.target == "B" and d.depth == 3
