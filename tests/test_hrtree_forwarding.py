"""HR-tree, Sentry, and forwarding-logic tests (+ hypothesis invariants)."""
import random

from hypothesis import given, settings, strategies as st

from repro.core import hrtree, sentry
from repro.core.forwarding import (Decision, ForwardingConfig, PeerInfo,
                                   decide)


def make_tree(lengths=(32,), default_chunk=16):
    return hrtree.HRTree(lengths, bits=8, default_chunk=default_chunk)


def test_insert_then_search_finds_holder():
    t = make_tree()
    toks = list(range(128))
    t.insert_tokens(toks, "A")
    holders, d = t.search_tokens(toks, tau=2)
    assert "A" in holders and d >= 2


def test_prefix_semantics():
    t = make_tree()
    shared = list(range(64))
    t.insert_tokens(shared + [500] * 32, "A")
    # query sharing only the 64-token prefix still matches at partial depth
    holders, d = t.search_tokens(shared + [900] * 32, tau=1)
    assert "A" in holders
    # totally different prompt: no match
    holders, d = t.search_tokens([7] * 128, tau=1)
    assert holders == []


def test_export_merge_roundtrip():
    t = make_tree()
    toks = list(range(96))
    t.insert_tokens(toks, "A")
    paths = t.export_paths("A")
    t2 = make_tree()
    t2.merge_paths(paths, "A")
    h1, d1 = t.search_tokens(toks, tau=1)
    h2, d2 = t2.search_tokens(toks, tau=1)
    assert h1 == h2 and d1 == d2


def test_remove_holder_and_expire():
    t = make_tree()
    t.insert_tokens(list(range(64)), "A", ts=1.0)
    t.insert_tokens(list(range(64)), "B", ts=5.0)
    t.remove_holder("A")
    holders, _ = t.search_tokens(list(range(64)), tau=1)
    assert holders == ["B"]
    t.expire(before_ts=10.0)
    holders, _ = t.search_tokens(list(range(64)), tau=1)
    assert holders == []


def test_false_positive_rate_math():
    t = make_tree()
    assert t.false_positive_rate(3) == (1 / 256) ** 3


@given(st.lists(st.integers(0, 1000), min_size=16, max_size=200),
       st.integers(0, 3))
@settings(max_examples=30, deadline=None)
def test_hrtree_inserted_always_found(tokens, tau):
    t = make_tree()
    t.insert_tokens(tokens, "X")
    n_hashes = len(hrtree.preprocess(tokens, t.lengths, t.bits,
                                     t.default_chunk))
    holders, d = t.search_tokens(tokens, tau=tau)
    assert d == n_hashes
    if d >= tau:
        assert "X" in holders


# ---------------------------------------------------------------- Sentry
def test_sentry_length_equations():
    assert sentry.build_lengths([32, 64, 128], 8) == [32, 8, 24, 8, 56]
    assert sentry.build_lengths([], 8) == []
    assert sentry.build_lengths([16], 4) == [16]


def test_sentry_detects_common_prefix():
    s = sentry.Sentry(sentry.SentryConfig(min_support=5, min_len=16,
                                          probe_stride=16))
    common = tuple(range(48))
    rng = random.Random(0)
    for i in range(40):
        tail = tuple(rng.randrange(2000, 3000) for _ in range(40))
        s.observe(common + tail)
    lengths = s.detect_prompt_lengths()
    assert lengths and max(lengths) >= 32  # found the shared prefix


# ---------------------------------------------------------------- Forwarding
def _tree_with(holder, tokens):
    t = make_tree()
    t.insert_tokens(tokens, holder)
    return t


def test_forward_match_prefers_cache_holder():
    toks = list(range(128))
    t = _tree_with("A", toks)
    peers = {"A": PeerInfo("A", 5, 3), "B": PeerInfo("B", 5, 0)}
    d = decide(ForwardingConfig(load_threshold=4.0), t, peers, toks)
    assert d.reason == "cache_hit" and d.target == "A"


def test_forward_overloaded_holder_falls_back():
    toks = list(range(128))
    t = _tree_with("A", toks)
    peers = {"A": PeerInfo("A", 5, 100), "B": PeerInfo("B", 5, 1)}
    d = decide(ForwardingConfig(load_threshold=4.0), t, peers, toks)
    assert d.reason == "load_balance" and d.target == "B"


def test_forward_relative_load_respects_hw_score():
    toks = [9] * 64  # miss
    t = make_tree()
    # A: 10 active on hw 10 (rel 1.0); B: 2 active on hw 1 (rel 2.0)
    peers = {"A": PeerInfo("A", 10, 10), "B": PeerInfo("B", 1, 2)}
    d = decide(ForwardingConfig(), t, peers, toks)
    assert d.target == "A"


def test_forward_tiebreak_spreads():
    t = make_tree()
    peers = {f"n{i}": PeerInfo(f"n{i}", 5, 0) for i in range(4)}
    targets = {decide(ForwardingConfig(), t, peers,
                      [seed] * 40).target for seed in range(40)}
    assert len(targets) >= 3
