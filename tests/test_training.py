"""Optimizer, train step, microbatching, checkpoint, compression, FT."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base
from repro.models.lm import build_model
from repro.training import checkpoint as ckpt_lib
from repro.training import compression, optimizer as opt_lib
from repro.training.data import (TOOLUSE, MarkovCorpus, MixedWorkload,
                                 WorkloadGen, poisson_arrivals)
from repro.training.fault_tolerance import (SimulatedCluster,
                                            StragglerPolicy, SupervisorConfig,
                                            TrainSupervisor)
from repro.training.train_step import make_train_step


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = base.get_config("h2o-danube-1.8b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_training_reduces_loss(tiny_setup):
    cfg, model, params = tiny_setup
    adamw = opt_lib.AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=30)
    step = jax.jit(make_train_step(cfg, model, adamw, block_q=32))
    opt = opt_lib.init_state(params)
    corpus = MarkovCorpus(cfg.vocab, seed=0)
    losses = []
    for b in corpus.batches(4, 32, 25):
        params, opt, m = step(params, opt,
                              {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.1
    assert np.isfinite(losses).all()


def test_microbatch_equals_full_batch(tiny_setup):
    cfg, model, params = tiny_setup
    adamw = opt_lib.AdamWConfig(lr=1e-3)
    s1 = jax.jit(make_train_step(cfg, model, adamw, microbatches=1,
                                 block_q=32))
    s2 = jax.jit(make_train_step(cfg, model, adamw, microbatches=2,
                                 block_q=32))
    corpus = MarkovCorpus(cfg.vocab, seed=0)
    b = next(corpus.batches(4, 32, 1))
    batch = {k: jnp.asarray(v) for k, v in b.items()}
    opt = opt_lib.init_state(params)
    p1, _, m1 = s1(params, opt, batch)
    p2, _, m2 = s2(params, opt, batch)
    for a, b2 in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b2),
                                   rtol=2e-4, atol=2e-5)


def test_schedule_warmup_and_decay():
    c = opt_lib.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_ratio=0.1)
    lrs = [float(opt_lib.schedule(c, jnp.asarray(s))) for s in
           (1, 10, 50, 100)]
    assert lrs[0] < lrs[1] == pytest.approx(1.0)
    assert lrs[1] > lrs[2] > lrs[3] >= 0.1 - 1e-6


# ---------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_prune(tiny_setup):
    cfg, model, params = tiny_setup
    opt = opt_lib.init_state(params)
    with tempfile.TemporaryDirectory() as d:
        for s in (10, 20, 30, 40):
            ckpt_lib.save(d, s, (params, opt))
        ckpt_lib.prune(d, keep=2)
        assert ckpt_lib.latest_step(d) == 40
        (p2, o2), step = ckpt_lib.restore(d, 40, (params, opt))
        assert step == 40
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # pruned steps are gone
        assert not (os.path.exists(os.path.join(d, "step_00000010")))


# ---------------------------------------------------------------- compression
def test_int8_quantization_error_bound():
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(0, 1, (128, 64)),
                          jnp.float32)}
    err = compression.init_error_state(g)
    sent, err2 = compression.compress_int8_ef(g, err)
    max_abs = float(jnp.max(jnp.abs(g["w"])))
    q_err = float(jnp.max(jnp.abs(sent["w"] - g["w"])))
    assert q_err <= max_abs / 127.0 + 1e-6
    # error feedback carries the residual
    np.testing.assert_allclose(np.asarray(err2["w"]),
                               np.asarray(g["w"] - sent["w"]), atol=1e-6)


def test_error_feedback_unbiased_over_time():
    """sum(transmitted) ~ sum(true grads) — EF compensates quantization."""
    rng = np.random.default_rng(1)
    g_true = [{"w": jnp.asarray(rng.normal(0, 1, (64,)), jnp.float32)}
              for _ in range(20)]
    err = compression.init_error_state(g_true[0])
    sent_sum = np.zeros(64)
    true_sum = np.zeros(64)
    for g in g_true:
        s, err = compression.compress_int8_ef(g, err)
        sent_sum += np.asarray(s["w"])
        true_sum += np.asarray(g["w"])
    np.testing.assert_allclose(sent_sum, true_sum, atol=0.05)


def test_compression_ratio():
    p = {"w": jnp.zeros((1000,)), "b": jnp.zeros((10,))}
    assert compression.compression_ratio_int8(p) > 3.5


# ---------------------------------------------------------------- fault tolerance
def test_supervisor_survives_failure_and_restarts():
    with tempfile.TemporaryDirectory() as d:
        cluster = SimulatedCluster(n_hosts=4, seed=0)
        cluster.inject_failure(host=2, step=33)

        def step_fn(state, step, n_hosts):
            return {"x": state["x"] + 1}

        sup = TrainSupervisor(
            SupervisorConfig(ckpt_dir=d, ckpt_every=10),
            cluster, step_fn,
            save_tree=lambda s: {"x": np.asarray(s["x"])},
            load_tree=lambda s, t, n_hosts: {"x": int(t["x"])})
        state, step = sup.run({"x": 0}, total_steps=60)
        assert step == 60
        kinds = [e[0] for e in sup.events]
        assert "restart" in kinds and "resume" in kinds
        # deterministic step fn: state must equal steps done since ckpt math
        assert state["x"] >= 60


def test_straggler_detection_and_eviction():
    pol = StragglerPolicy(kappa=2.0, evict_after=2)
    times = {0: 1.0, 1: 1.0, 2: 5.0, 3: 1.1}
    v1 = pol.observe(times)
    assert 2 in v1["slow"] and not v1["evict"]
    v2 = pol.observe(times)
    assert 2 in v2["evict"]


# ---------------------------------------------------------------- workloads
def test_workload_statistics():
    g = WorkloadGen(TOOLUSE, seed=0, scale=0.1)
    qs = [g.sample() for _ in range(300)]
    lens = [len(q.tokens) for q in qs]
    # scaled mean ~ (6400 + 800) * 0.1
    assert 400 < np.mean(lens) < 1100
    # zipf: the most popular prefix dominates
    from collections import Counter
    c = Counter(q.prefix_id for q in qs)
    assert c.most_common(1)[0][1] > len(qs) * 0.15


def test_mixed_workload_ratio():
    m = MixedWorkload(seed=0, scale=0.05)
    from collections import Counter
    c = Counter(m.sample().workload for _ in range(600))
    assert c["Coding"] > c["ToolUse"] > c["LongQA"]


def test_poisson_arrivals_monotone():
    ts = poisson_arrivals(10.0, 100, seed=0)
    assert all(b > a for a, b in zip(ts, ts[1:]))
    assert 5 < ts[-1] < 20  # ~10s for 100 arrivals at 10/s
