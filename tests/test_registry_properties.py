"""Registry (regions/groups/signed lists) + property tests for consensus
safety and the anonymity metric."""
import random

from hypothesis import given, settings, strategies as st

from repro.core import anonymity, ed25519
from repro.core.consensus import Challenge, SignedResponse, \
    VerificationCommittee
from repro.overlay.registry import (MODEL_GROUP_MAX, NodeRecord, Registry,
                                    SignedList)


def _mk_registry(n_vn=4, use_crypto=True):
    keys = {f"vn{i}": ed25519.SigningKey(bytes([50 + i]) * 32)
            for i in range(n_vn)}
    return Registry(keys, use_crypto=use_crypto)


def test_signed_list_verifies_and_tamper_fails():
    reg = _mk_registry()
    for i in range(5):
        reg.register_user(NodeRecord(f"u{i}", dh_pub=bytes([i]) * 32))
    sl = reg.user_list()
    assert sl.verify(reg.committee_pubs)
    # tamper: drop a record
    bad = SignedList(sl.records[:-1], sl.signatures)
    assert not bad.verify(reg.committee_pubs)


def test_minority_signatures_rejected():
    reg = _mk_registry(n_vn=4)
    reg.register_user(NodeRecord("u0", dh_pub=b"\x01" * 32))
    sl = reg.user_list()
    # keep only 2 of 4 signatures: 2*3 <= 2*4 -> invalid
    sl.signatures = dict(list(sl.signatures.items())[:2])
    assert not sl.verify(reg.committee_pubs)


def test_model_group_splitting():
    reg = _mk_registry(use_crypto=False)
    for i in range(120):
        reg.register_model(NodeRecord(f"m{i}", llm="llama",
                                      region=f"r{i % 2}"))
    groups = reg.model_groups("llama")
    assert all(len(g) <= MODEL_GROUP_MAX for g in groups)
    assert sum(len(g) for g in groups) == 120
    # regions never mix within a group
    for g in groups:
        assert len({r.region for r in g}) == 1


# ---------------------------------------------------------------- consensus
@given(st.integers(min_value=4, max_value=10),
       st.data())
@settings(max_examples=15, deadline=None)
def test_consensus_safety_under_f_byzantine(n, data):
    """With <= f byzantine members (n >= 3f+1), honest epochs commit and
    committed scores equal the honest scoring function."""
    f = (n - 1) // 3
    byz = set(data.draw(st.lists(st.integers(0, n - 1), max_size=f,
                                 unique=True)))

    def fn(pairs):
        return 0.7
    com = VerificationCommittee(n, [fn] * n, byzantine=byz)
    com.agree_challenges([Challenge("m0", (1, 2, 3))])

    def collect(leader_ix, challenges):
        return [SignedResponse("m0", (1, 2, 3), (4, 5), b"", True)]

    res = com.run_epoch(collect)
    if com.log[-1].leader in byz:
        assert not res.committed       # byzantine leader cannot commit junk
    else:
        assert res.committed
        assert abs(res.scores["m0"] - 0.7) < 1e-9


@given(st.floats(min_value=0.0, max_value=0.3),
       st.integers(min_value=100, max_value=2000))
@settings(max_examples=20, deadline=None)
def test_anonymity_metric_bounded(f, N):
    rng = random.Random(0)
    v = anonymity.gentorrent_anonymity(N, f, 4, 3, rng)
    assert 0.0 <= v <= 1.0 + 1e-9
