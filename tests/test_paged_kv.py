"""Paged KV pool: allocator invariants, dense-vs-paged token parity, and
zero-copy prefix sharing (aliased pages, refcount assertions).

Deliberately hypothesis-free so it runs even without dev extras installed;
the hypothesis property suite for the allocator lives in
tests/test_page_pool_props.py.
"""
import random

import jax
import numpy as np
import pytest

from repro.configs import base
from repro.models.lm import build_model
from repro.serving.engine import RealEngine, Request
from repro.serving.page_pool import (NULL_PAGE, OutOfPages, PageAllocator,
                                     PagedHandle)
from repro.serving.prefix_cache import BLOCK
from repro.serving.scheduler import Scheduler


@pytest.fixture(scope="module")
def gt():
    cfg = base.get_config("gentorrent-llama3-8b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompts(cfg, n, lengths=(20, 40, 36, 33, 64)):
    return [[(37 * i + j) % cfg.vocab
             for j in range(lengths[i % len(lengths)])] for i in range(n)]


# ----------------------------------------------------------- allocator
def test_allocator_basic_lifecycle():
    a = PageAllocator(8)
    p = a.alloc(3)
    assert len(set(p)) == 3 and NULL_PAGE not in p
    assert a.free_count == 4 and a.used_count == 3
    a.incref(p[:1])
    a.decref(p)                      # p[0] still held by the alias
    assert a.refcount(p[0]) == 1 and a.free_count == 6
    a.decref(p[:1])
    assert a.free_count == 7
    a.check()


def test_allocator_errors():
    a = PageAllocator(4)
    with pytest.raises(OutOfPages):
        a.alloc(4)                   # page 0 is reserved scratch
    p = a.alloc(1)
    a.decref(p)
    with pytest.raises(ValueError):
        a.decref(p)                  # double free
    with pytest.raises(ValueError):
        a.incref(p)                  # incref of a free page
    with pytest.raises(ValueError):
        a.incref([NULL_PAGE])        # scratch is never referenceable
    a.check()


def test_allocator_randomized_invariants():
    """Deterministic random churn: model refcounts in pure python and
    check the allocator agrees; aliased pages survive their allocator."""
    random.seed(11)
    a = PageAllocator(32)
    live = {}                        # page -> model refcount
    for _ in range(2000):
        op = random.random()
        if op < 0.4 and a.free_count:
            n = random.randint(1, min(3, a.free_count))
            for p in a.alloc(n):
                live[p] = 1
        elif op < 0.6 and live:
            p = random.choice(list(live))
            a.incref([p])
            live[p] += 1
        elif live:
            p = random.choice(list(live))
            a.decref([p])
            live[p] -= 1
            if not live[p]:
                del live[p]
        for p, rc in live.items():
            assert a.refcount(p) == rc
        assert a.used_count == len(live)
        a.check()


# ------------------------------------------------------- parity vs dense
def test_paged_generate_matches_dense(gt):
    """Same model, same requests: the paged engine's outputs are token-
    identical to the PR-1 dense path (miss path: chunked paged prefill +
    paged decode vs boot prefill + dense decode)."""
    cfg, model, params = gt
    dense = RealEngine(cfg, model, params, max_len=128, paged=False)
    paged = RealEngine(cfg, model, params, max_len=128)
    assert paged.paged and not dense.paged
    for i, p in enumerate(_prompts(cfg, 5)):
        rd = dense.generate(Request(i, p, max_new=8))
        rp = paged.generate(Request(i, p, max_new=8))
        assert rd.output == rp.output


def test_paged_scheduler_matches_dense_scheduler(gt):
    cfg, model, params = gt
    prompts = _prompts(cfg, 6)
    ref = {}
    eng_d = RealEngine(cfg, model, params, max_len=128, paged=False)
    sd = Scheduler(eng_d, max_active=3)
    for i, p in enumerate(prompts):
        sd.submit(Request(i, p, max_new=8))
    ref = {r.req_id: r.output for r in sd.run()}

    eng_p = RealEngine(cfg, model, params, max_len=128)
    sp = Scheduler(eng_p, max_active=3)
    for i, p in enumerate(prompts):
        sp.submit(Request(i, p, max_new=8))
    out = {r.req_id: r.output for r in sp.run()}
    assert out == ref
    # the paged pool decode also compiled exactly once across occupancies
    assert eng_p.batched_traces == 1
    eng_p.allocator.check()


def test_paged_hit_matches_cold_output(gt):
    """A prefix-hit admission (aliased pages + suffix-only prefill) must
    reproduce the cache-free output exactly."""
    cfg, model, params = gt
    shared = [7] * 40
    cold = RealEngine(cfg, model, params, max_len=128)
    a = cold.generate(Request(0, shared + [1, 2, 3], max_new=6)).output

    eng = RealEngine(cfg, model, params, max_len=128)
    eng.generate(Request(1, shared + [9, 9], max_new=6))     # warm the cache
    r = eng.generate(Request(2, shared + [1, 2, 3], max_new=6))
    assert r.cached_tokens >= BLOCK
    assert r.output == a


# ------------------------------------------------- zero-copy prefix sharing
def test_hit_admission_aliases_pages_no_copy(gt):
    """The acceptance check: admitting a prefix-hit request bumps the
    holder's page refcounts and allocates pages only from the divergence
    point — no KV bytes move."""
    cfg, model, params = gt
    eng = RealEngine(cfg, model, params, max_len=128)
    shared = [3] * 64                                  # 2 full blocks
    eng.generate(Request(0, shared + [5], max_new=2))
    matched, entry = eng.prefix_cache.peek(shared + [8] * 8)
    assert matched == 64 and isinstance(entry.handle, PagedHandle)
    cached_pages = entry.handle.pages[:2]
    rc_before = [eng.allocator.refcount(p) for p in cached_pages]
    used_before = eng.allocator.used_count

    st = eng.prefill_request(Request(1, shared + [8] * 8, max_new=4))
    # the admitted request's first two pages ARE the cache entry's pages
    assert tuple(st.pages[:2]) == tuple(cached_pages)
    for p, rc0 in zip(cached_pages, rc_before):
        assert eng.allocator.refcount(p) == rc0 + 1    # aliased, not copied
    # only the divergence suffix allocated fresh pages: 8 suffix tokens in
    # one block -> exactly one new page beyond the aliased prefix
    assert eng.allocator.used_count == used_before + 1
    assert len(st.pages) == 3 and st.matched == 64
    eng.release_pages(st.pages)
    eng.allocator.check()


def test_full_hit_replay_never_writes_aliased_pages(gt):
    """A block-aligned fully cached prompt replays its last token query-
    only: the aliased pages' contents must be bit-identical afterwards."""
    cfg, model, params = gt
    eng = RealEngine(cfg, model, params, max_len=128)
    prompt = [11] * 64                                 # block-aligned
    eng.generate(Request(0, prompt, max_new=2))
    _, entry = eng.prefix_cache.peek(prompt)
    pages = list(entry.handle.pages)
    before = [np.asarray(leaf[:, pages])
              for leaf in jax.tree.leaves(eng.arena)]
    st = eng.prefill_request(Request(1, prompt, max_new=2))
    after = [np.asarray(leaf[:, pages])
             for leaf in jax.tree.leaves(eng.arena)]
    for b, a in zip(before, after):
        np.testing.assert_array_equal(b, a)
    assert st.matched == 64
    eng.release_pages(st.pages)


def test_completion_inserts_by_reference_and_releases(gt):
    """Completion hands pages to the prefix cache by reference; evicting
    the entry returns them to the free list only once no request uses
    them."""
    cfg, model, params = gt
    eng = RealEngine(cfg, model, params, max_len=128)
    free0 = eng.allocator.free_count
    eng.generate(Request(0, [9] * 40, max_new=8))      # pos 48 -> 1 page kept
    _, entry = eng.prefix_cache.peek([9] * 40)
    kept = entry.handle.pages
    assert len(kept) == 1 and eng.allocator.refcount(kept[0]) == 1
    # request's own references were dropped; only the entry's survive
    assert eng.allocator.free_count == free0 - 1
    while eng.prefix_cache.pop_lru():
        pass
    assert eng.allocator.free_count == free0
    eng.allocator.check()


def test_allocator_pressure_evicts_prefix_cache(gt):
    """With a tiny arena, sustained distinct traffic must recycle pages
    through LRU eviction instead of dying with OutOfPages."""
    cfg, model, params = gt
    eng = RealEngine(cfg, model, params, max_len=128, num_pages=13)
    for i in range(6):
        out = eng.generate(Request(i, [(53 * i + j) % cfg.vocab
                                       for j in range(40)], max_new=6))
        assert len(out.output) == 6
    eng.allocator.check()


def test_cached_prefixes_deduped_by_entry():
    """An entry registers one chain key per block depth; the HR-tree
    broadcast view must count it once, not once per key."""
    from repro.serving.prefix_cache import PrefixCache
    pc = PrefixCache(block=8)
    pc.insert(list(range(40)), "A", 10)          # 5 block depths, 1 entry
    pc.insert(list(range(200, 216)), "B", 10)    # 2 depths, 1 entry
    got = pc.cached_prefixes()
    assert len(got) == 2
    assert sorted(ln for ln, _ in got) == [16, 40]


def test_model_node_reports_free_page_pressure(gt):
    """The HR-tree sync broadcast carries the paged arena's free-page
    pressure, and peers record it."""
    cfg, model, params = gt
    from repro.overlay.model_node import ModelNode
    eng = RealEngine(cfg, model, params, max_len=128, num_pages=17)
    node = ModelNode("m0", use_crypto=False, real_engine=eng)
    assert node._kv_pressure() == 0.0
    pages = eng.alloc_pages(4)
    assert node._kv_pressure() == pytest.approx(4 / 16)
    peer = ModelNode("m1", use_crypto=False)
    peer._handle_sync(None, {"from": "m0", "paths": [], "active": 1,
                             "hw": 5.0, "kv_pressure": node._kv_pressure()})
    assert peer.peers["m0"].kv_pressure == pytest.approx(4 / 16)
    assert peer._kv_pressure() == 0.0            # latency-model node
    eng.release_pages(pages)


def test_pool_memory_scales_with_live_tokens(gt):
    """The dense pool pins max_active x max_len KV regardless of
    occupancy; the paged pool's footprint is the live pages."""
    cfg, model, params = gt
    eng_d = RealEngine(cfg, model, params, max_len=128, paged=False)
    sd = Scheduler(eng_d, max_active=4)
    eng_p = RealEngine(cfg, model, params, max_len=128)
    sp = Scheduler(eng_p, max_active=4)
    for s in (sd, sp):
        s.submit(Request(0, [5] * 20, max_new=4))
        s.step()                                       # one slot occupied
    assert sp.kv_bytes_in_use() < sd.kv_bytes_in_use() / 4
    sd.run(), sp.run()
