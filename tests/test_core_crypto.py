"""Unit + property tests for the S-IDA stack: GF(256), ChaCha20, Shamir,
Rabin IDA, S-IDA."""
import itertools
import os

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import chacha, gf256, ida, shamir, sida


# ---------------------------------------------------------------- GF(256)
def test_gf256_mul_inverse():
    a = np.arange(1, 256, dtype=np.uint8)
    for x in [1, 2, 3, 7, 131, 255]:
        prod = gf256.mul(gf256.mul(a, np.uint8(x)),
                         gf256.inv(np.uint8(x)))
        assert np.array_equal(prod, a)


def test_gf256_distributive():
    rng = np.random.default_rng(0)
    a, b, c = (rng.integers(0, 256, 64, dtype=np.uint8) for _ in range(3))
    left = gf256.mul(a, b ^ c)
    right = gf256.mul(a, b) ^ gf256.mul(a, c)
    assert np.array_equal(left, right)


def test_gf256_matrix_inverse():
    rng = np.random.default_rng(1)
    for _ in range(10):
        while True:
            M = rng.integers(0, 256, (5, 5), dtype=np.uint8)
            try:
                Mi = gf256.mat_inv(M)
                break
            except np.linalg.LinAlgError:
                continue
        assert np.array_equal(gf256.matmul(M, Mi),
                              np.eye(5, dtype=np.uint8))


# ---------------------------------------------------------------- ChaCha20
def test_chacha_rfc8439_vector():
    key = bytes(range(32))
    nonce = bytes.fromhex("000000090000004a00000000")
    ks = chacha.keystream(key, nonce, 1, counter=1)
    expect = bytes.fromhex(
        "10f1e7e4d13b5915500fdd1fa32071c4c7d1f4c733c068030422aa9ac3d46c4e"
        "d2826446079faa0914c2d705d98b02a2b5129cd1de164eb9cbd083e8a2503c4e")
    assert ks[:64] == expect


@given(st.binary(min_size=0, max_size=2048))
@settings(max_examples=25, deadline=None)
def test_chacha_roundtrip(data):
    key = bytes(range(32))
    ct = chacha.encrypt(data, key)
    assert chacha.decrypt(ct, key) == data
    if len(data) > 8:
        assert ct[12:] != data  # actually encrypted


# ---------------------------------------------------------------- Shamir
@given(st.binary(min_size=1, max_size=128),
       st.integers(min_value=1, max_value=6),
       st.integers(min_value=0, max_value=4))
@settings(max_examples=25, deadline=None)
def test_shamir_any_k_of_n(secret, k, extra):
    n = k + extra
    shares = shamir.split(secret, n, k)
    # recover from the LAST k shares (arbitrary subset)
    assert shamir.combine(shares[-k:], k) == secret


def test_shamir_below_threshold_no_info():
    secret = b"\x00" * 32
    # 2 shares: reconstructing with a wrong 3rd share gives garbage, and
    # the 2 shares alone are uniformly distributed (can't equal secret
    # deterministically) — statistical smoke check over trials
    hits = 0
    for t in range(50):
        s2 = shamir.split(os.urandom(32), 5, 3)[:2]
        if shamir.combine(s2 + [(5, os.urandom(32))], 3) == secret:
            hits += 1
    assert hits == 0


# ---------------------------------------------------------------- Rabin IDA
@given(st.binary(min_size=0, max_size=512),
       st.integers(min_value=1, max_value=5),
       st.integers(min_value=0, max_value=3))
@settings(max_examples=25, deadline=None)
def test_ida_roundtrip(data, k, extra):
    n = k + extra
    frags = ida.split(data, n, k)
    assert ida.combine(frags[-k:], n, k) == data


def test_ida_every_combination():
    data = os.urandom(199)
    n, k = 6, 3
    frags = ida.split(data, n, k)
    for combo in itertools.combinations(range(n), k):
        assert ida.combine([frags[i] for i in combo], n, k) == data


def test_ida_fragment_size_near_optimal():
    data = os.urandom(3000)
    frags = ida.split(data, 4, 3)
    assert len(frags[0][1]) <= len(data) // 3 + 8


# ---------------------------------------------------------------- S-IDA
@given(st.binary(min_size=0, max_size=1024),
       st.integers(min_value=1, max_value=4),
       st.integers(min_value=1, max_value=3))
@settings(max_examples=25, deadline=None)
def test_sida_roundtrip(msg, k, extra):
    n = k + extra
    cloves = sida.make_cloves(msg, n, k)
    assert sida.recover(cloves[-k:]) == msg
    assert sida.recover(cloves) == msg


def test_sida_below_k_fails():
    cloves = sida.make_cloves(b"secret prompt", 4, 3)
    with pytest.raises(ValueError):
        sida.recover(cloves[:2])


def test_sida_clove_wire_roundtrip():
    cloves = sida.make_cloves(b"x" * 100, 4, 3)
    decoded = [sida.Clove.decode(c.encode()) for c in cloves]
    assert sida.recover(decoded[:3]) == b"x" * 100
