"""Batched credibility must equal the per-pair scoring exactly."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import base
from repro.core.verification import (VerifierModel, credibility,
                                     credibility_batch)
from repro.models.lm import build_model


@pytest.fixture(scope="module")
def verifier():
    cfg = base.get_config("gentorrent-llama3-8b").reduced()
    cfg = dataclasses.replace(cfg, vocab=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return VerifierModel(cfg, model, params)


def test_batch_matches_single(verifier):
    rng = np.random.default_rng(0)
    pairs = []
    for i in range(4):
        p = rng.integers(0, 128, size=8 + i).tolist()
        r = rng.integers(0, 128, size=5 + 2 * i).tolist()
        pairs.append((p, r))
    singles = [credibility(verifier, p, r) for p, r in pairs]
    batched = credibility_batch(verifier, pairs)
    np.testing.assert_allclose(batched, singles, rtol=2e-3, atol=1e-4)


def test_batch_empty(verifier):
    assert credibility_batch(verifier, []) == []
    assert credibility_batch(verifier, [([1, 2], [])]) == [0.0]
