"""Prefix-affinity overlay forwarding + batched admission prefill.

Multi-node acceptance: with 2+ model nodes on SimNet, affinity routing is
token-identical to load-only routing while doing strictly less duplicate
prefill work, and a whole admission round of co-routed siblings costs ONE
batched ``prefill_paged`` dispatch (shared chunk grid, masked tail rows).

Deliberately hypothesis-free so it runs even without dev extras installed.
"""
import jax
import pytest

from repro.configs import base
from repro.core.forwarding import ForwardingConfig
from repro.models.lm import build_model
from repro.net import messages
from repro.net.simnet import SimNet
from repro.overlay.model_node import ModelNode
from repro.overlay.probe import ResponseSink, direct_payload
from repro.serving.engine import RealEngine, Request
from repro.serving.scheduler import Scheduler


@pytest.fixture(scope="module")
def gt():
    cfg = base.get_config("gentorrent-llama3-8b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


SHARED = [7] * 64                       # two full blocks


# ---------------------------------------------------- batched admission
def test_admission_round_is_single_prefill_dispatch(gt):
    """K co-routed siblings whose divergence suffixes fit one BLOCK cost
    exactly ONE prefill_paged dispatch for the whole admission round."""
    cfg, model, params = gt
    eng = RealEngine(cfg, model, params, max_len=128)
    s = Scheduler(eng, max_active=4)
    s.submit(Request(0, SHARED + [1] * 8, max_new=2))    # seed the cache
    s.run()
    s.done.clear()
    d0 = eng.prefill_dispatches
    for i in range(3):
        s.submit(Request(10 + i, SHARED + [20 + i] * 8, max_new=4))
    s.step()                             # one admission round, 3 siblings
    assert s.metrics["admitted"] == 4
    assert eng.prefill_dispatches - d0 == 1
    out = {r.req_id: r.output for r in s.run()}
    assert all(len(v) == 4 for v in out.values())
    assert all(r.cached_tokens == 64 for r in s.done + [])


def test_batched_admission_matches_per_request(gt):
    """Mixed suffix lengths across the shared chunk grid (masked tail
    rows) must reproduce the per-request admission outputs exactly."""
    cfg, model, params = gt
    lengths = (20, 90, 40, 33)
    prompts = [[(37 * i + j) % cfg.vocab for j in range(n)]
               for i, n in enumerate(lengths)]
    ref_eng = RealEngine(cfg, model, params, max_len=128)
    ref = {i: ref_eng.generate(Request(i, p, max_new=6)).output
           for i, p in enumerate(prompts)}

    eng = RealEngine(cfg, model, params, max_len=128)
    states = eng.prefill_requests(
        [Request(i, p, max_new=6) for i, p in enumerate(prompts)], batch=4)
    s = Scheduler(eng, max_active=4)
    for i, p in enumerate(prompts):
        s.submit(Request(i, p, max_new=6))
    out = {r.req_id: r.output for r in s.run()}
    assert out == ref
    # the direct prefill_requests states agree with per-request admission
    for st, p in zip(states, prompts):
        assert st.pos == len(p)
        eng.release_pages(st.pages)
    # the shared grid compiled once despite per-round occupancy changing
    assert eng.batched_prefill_traces == 1
    eng.allocator.check()


def test_batched_admission_full_hit_replay(gt):
    """A block-aligned fully cached prompt admitted in a batch replays
    query-only (no grid step for it) and still decodes correctly."""
    cfg, model, params = gt
    eng = RealEngine(cfg, model, params, max_len=128)
    s = Scheduler(eng, max_active=2)
    s.submit(Request(0, SHARED, max_new=4))
    ref = {r.req_id: r.output for r in s.run()}[0]
    s.submit(Request(1, SHARED, max_new=4))              # full 64-token hit
    s.submit(Request(2, SHARED + [9] * 4, max_new=4))    # 4-token suffix
    out = {r.req_id: r.output for r in s.run()}
    assert out[1] == ref
    eng.allocator.check()


# -------------------------------------------------- multi-node affinity
def _run_mode(gt, affinity: bool):
    """Two model nodes, seed the prefix on m0, inject siblings at m1.

    m1's (stale) view shows m0 busy-but-under-threshold, so load-only
    routing keeps siblings local while affinity routing follows the
    sketch to the prefix holder."""
    cfg, model, params = gt
    net = SimNet(seed=3)
    fwd = ForwardingConfig(affinity=affinity)
    nodes = [ModelNode(f"m{i}", use_crypto=False, fwd_cfg=fwd,
                       real_engine=RealEngine(cfg, model, params,
                                              max_len=128))
             for i in range(2)]
    for n in nodes:
        net.add_node(n.node_id, n)
    members = [n.node_id for n in nodes]
    for n in nodes:
        n.join_group(members)
    sink = ResponseSink()
    net.add_node("sink", sink)
    nodes[0]._process(net, direct_payload("seed", SHARED + [1] * 8),
                      forwarded=True)
    net.run_until(net.t + 30)
    for n in nodes:
        n.broadcast_state(net)
    net.run_until(net.t + 5)
    # stale busy view of m0: 3 actives on hw 5 = relative load 0.6 — the
    # optimistic forward echo raises it to 1.0 by the third sibling,
    # exactly at the affinity_load_max bound, so ALL siblings co-route
    # while load-only routing (self at 0.0..0.4) keeps them local
    nodes[1].peers["m0"].active_requests = 3
    for i in range(3):
        net.call_after(0.01, nodes[1]._process, net,
                       direct_payload(f"sib{i}", SHARED + [10 + i] * 8))
    net.run_until(net.t + 60)
    assert len(sink.got) == 4
    return nodes, sink


def test_affinity_multinode_parity_and_fewer_prefill_bytes(gt):
    aff_nodes, aff = _run_mode(gt, affinity=True)
    lb_nodes, lb = _run_mode(gt, affinity=False)
    # token-identical outputs regardless of where routing lands
    assert aff.got == lb.got
    # affinity followed the sketch to the holder...
    assert aff_nodes[1].metrics["affinity_hits"] == 3
    assert aff_nodes[1].metrics["forwarded_out"] == 3
    # ...so only the divergence tails were prefilled (seed 72 + 3 x 8),
    # and the whole sibling round was ONE batched dispatch (72-token seed
    # = 3 chunk steps, 8-token sibling suffixes = 1 shared step)
    aff_eng = [n.real_engine for n in aff_nodes]
    assert aff_eng[0].prefill_tokens == 72 + 3 * 8
    assert aff_eng[1].prefill_tokens == 0
    assert aff_eng[0].prefill_dispatches == 3 + 1
    # load-only kept siblings on the idle node and re-prefilled the
    # shared prefix from scratch there
    lb_eng = [n.real_engine for n in lb_nodes]
    assert lb_nodes[1].metrics["affinity_hits"] == 0
    assert lb_eng[1].prefill_tokens == 3 * 72
    dup = sum(e.prefill_tokens for e in lb_eng) \
        - sum(e.prefill_tokens for e in aff_eng)
    assert dup >= len(SHARED)            # duplicate-prefill work eliminated


# ------------------------------------------------------- sync plumbing
def test_sync_broadcast_carries_sketch(gt):
    net = SimNet()
    a, b = ModelNode("a", use_crypto=False), ModelNode("b", use_crypto=False)
    for n in (a, b):
        net.add_node(n.node_id, n)
        n.join_group(["a", "b"])
    toks = list(range(64))
    a.engine.prefix_cache.insert(toks, None, 64 * 1024)
    a.broadcast_state(net)
    net.run_until(net.t + 5)
    assert b.peers["a"].prefix_sketch is not None
    from repro.core.forwarding import PrefixSketch
    from repro.serving.prefix_cache import _chain_hashes
    sk = PrefixSketch.from_bytes(b.peers["a"].prefix_sketch)
    assert sk.hit_depth(_chain_hashes(toks)) == 2
    # local self-view refreshed too (decide() sees its own cache)
    assert a.peers["a"].prefix_sketch == b.peers["a"].prefix_sketch


def test_hr_sync_wire_format_accepts_optional_fields():
    ok = {"type": "hr_sync", "from": "m0", "paths": [], "active": 0,
          "hw": 5.0, "kv_pressure": 0.25, "sketch": b"\x00" * 64}
    assert messages.validate(ok)
    assert messages.validate({"type": "hr_sync", "from": "m0",
                              "paths": [], "active": 0, "hw": 5.0})
    bad = dict(ok, sketch="not-bytes")
    assert not messages.validate(bad)
    enc = messages.encode(ok)
    dec = list(messages.Decoder().feed(enc))
    assert dec and dec[0]["sketch"] == b"\x00" * 64
