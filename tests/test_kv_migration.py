"""Cross-node KV page migration: pull-based page replication over the
overlay (kv_fetch / kv_pages) instead of re-prefilling vetoed prefixes.

Multi-node acceptance: with the prefix holder pressured out of affinity
routing, a second node pulls the prefix pages, admits the siblings with
ZERO prefill dispatches for the replicated blocks, and produces outputs
token-identical to prefill-from-scratch.  Plus unit coverage for the wire
codec, the export/import arena round trip, and the message schema.

Deliberately hypothesis-free so it runs even without dev extras installed.
"""
import jax
import numpy as np
import pytest

from repro.configs import base
from repro.core.forwarding import ForwardingConfig
from repro.models.lm import build_model
from repro.net import messages
from repro.net.simnet import SimNet
from repro.overlay.model_node import ModelNode
from repro.overlay.probe import ResponseSink, direct_payload
from repro.serving.engine import RealEngine, Request
from repro.serving.prefix_cache import BLOCK, _chain_hashes
from repro.training.compression import (compress_kv_blocks,
                                        decompress_kv_blocks)


@pytest.fixture(scope="module")
def gt():
    cfg = base.get_config("gentorrent-llama3-8b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


SHARED = [7] * 96                       # three full blocks


# ------------------------------------------------------------ wire codec
def test_kv_wire_codec_roundtrip():
    rng = np.random.default_rng(0)
    arr = rng.standard_normal((2, 3, 32, 2, 16)).astype(np.float32)
    raw = decompress_kv_blocks(compress_kv_blocks(arr, "raw"))
    np.testing.assert_array_equal(raw, arr)
    fp16 = decompress_kv_blocks(compress_kv_blocks(arr, "fp16"))
    assert fp16.dtype == np.float32     # cast back to the recorded dtype
    np.testing.assert_allclose(fp16, arr, rtol=1e-3, atol=1e-3)
    q = decompress_kv_blocks(compress_kv_blocks(arr, "int8"))
    # int8 with per-(repeat, page) max-abs scale: error <= scale/2
    scale = np.abs(arr).reshape(2, 3, -1).max(-1) / 127.0
    assert np.all(np.abs(q - arr) <= scale[..., None, None, None] / 2 + 1e-7)
    with pytest.raises(ValueError):
        compress_kv_blocks(arr, "gzip")


def test_kv_messages_schema():
    chains = [b"\x01" * 16, b"\x02" * 16]
    fetch = {"type": "kv_fetch", "from": "m1", "fetch_id": 1,
             "chains": chains, "depth": 2}
    assert messages.validate(fetch)
    pages = {"type": "kv_pages", "from": "m0", "fetch_id": 1, "ok": True,
             "seq": 0, "total": 1, "depth": 2, "data": b"\x00" * 32}
    assert messages.validate(pages)
    refusal = {"type": "kv_pages", "from": "m0", "fetch_id": 1, "ok": False}
    assert messages.validate(refusal)
    assert not messages.validate({"type": "kv_fetch", "from": "m1"})
    assert not messages.validate(dict(pages, data="not-bytes"))
    dec = list(messages.Decoder().feed(messages.encode(fetch)))
    assert dec and [bytes(c) for c in dec[0]["chains"]] == chains


# ------------------------------------------- engine export/import round trip
def test_export_import_pages_roundtrip(gt):
    """Raw-mode export/import lands byte-identical K/V in the importer's
    arena, registered under the same digests, with refcount parity on
    both allocators."""
    cfg, model, params = gt
    src = RealEngine(cfg, model, params, max_len=128)
    src.generate(Request(0, SHARED + [1] * 8, max_new=2))
    _, entry = src.prefix_cache.peek(SHARED)
    assert entry is not None and len(entry.handle.pages) >= 3
    src_free = src.allocator.free_count
    buf = src.export_pages(entry.handle, depth=3, mode="raw")
    assert buf["n_pages"] == 3
    # export is read-only: no refcount or allocator movement at the source
    assert src.allocator.free_count == src_free
    src.allocator.check()

    dst = RealEngine(cfg, model, params, max_len=128)
    chains = _chain_hashes(SHARED)
    handle = dst.import_pages(buf, chains)
    assert handle.length == 3 * BLOCK
    # the digests now resolve locally and the arena bytes match exactly
    matched, got = dst.prefix_cache.peek(SHARED)
    assert matched == 96 and got.handle is handle
    for sl, dl in zip(src.arena, dst.arena):
        for n in ("k", "v"):
            np.testing.assert_array_equal(
                np.asarray(sl[n][:, list(entry.handle.pages[:3])]),
                np.asarray(dl[n][:, list(handle.pages)]))
    # cache entry owns the imported pages at refcount 1
    assert all(dst.allocator.refcount(p) == 1 for p in handle.pages)
    dst.allocator.check()
    # admission aliases the imported prefix: zero prefill dispatches for
    # the replicated blocks, and releasing everything frees the pages
    d0 = dst.prefill_dispatches
    res = dst.generate(Request(1, SHARED + [9] * 8, max_new=2))
    assert res.cached_tokens == 96
    assert dst.prefill_dispatches - d0 == 1          # the 8-token tail only
    while dst.prefix_cache.pop_lru():
        pass
    assert dst.allocator.free_count == dst.num_pages - 1
    dst.allocator.check()


def test_import_rejects_short_chain(gt):
    cfg, model, params = gt
    src = RealEngine(cfg, model, params, max_len=128)
    src.generate(Request(0, SHARED, max_new=2))
    _, entry = src.prefix_cache.peek(SHARED)
    buf = src.export_pages(entry.handle, depth=3)
    dst = RealEngine(cfg, model, params, max_len=128)
    with pytest.raises(ValueError):
        dst.import_pages(buf, _chain_hashes(SHARED)[:2])
    dst.allocator.check()


# ------------------------------------------------------ multi-node flows
def _build(gt, replicate: bool):
    cfg, model, params = gt
    net = SimNet(seed=5)
    fwd = ForwardingConfig(replicate=replicate)
    nodes = [ModelNode(f"m{i}", use_crypto=False, fwd_cfg=fwd,
                       real_engine=RealEngine(cfg, model, params,
                                              max_len=128))
             for i in range(2)]
    for n in nodes:
        net.add_node(n.node_id, n)
    members = [n.node_id for n in nodes]
    for n in nodes:
        n.join_group(members)
    sink = ResponseSink()
    net.add_node("sink", sink)
    return net, nodes, sink


def _seed_and_pressure(net, nodes):
    """Seed the shared prefix on m0, sync sketches, then make m0 look
    pressured in m1's (stale) view: load above ``affinity_load_max`` AND
    a nearly-full arena — the regime where PR-3 affinity silently dropped
    the hit and re-prefilled."""
    nodes[0]._process(net, direct_payload("seed", SHARED + [1] * 8, 2),
                      forwarded=True)
    net.run_until(net.t + 30)
    for n in nodes:
        n.broadcast_state(net)
    net.run_until(net.t + 5)
    nodes[1].peers["m0"].active_requests = 6          # rel load 1.2
    nodes[1].peers["m0"].kv_pressure = 0.95


def _run_siblings(gt, replicate: bool):
    net, nodes, sink = _build(gt, replicate)
    _seed_and_pressure(net, nodes)
    eng1 = nodes[1].real_engine
    pre_tok, pre_disp = eng1.prefill_tokens, eng1.prefill_dispatches
    for i in range(3):
        net.call_after(0.01, nodes[1]._process, net,
                       direct_payload(f"sib{i}", SHARED + [10 + i] * 8, 4))
    net.run_until(net.t + 60)
    assert len(sink.got) == 4
    return nodes, sink, eng1.prefill_tokens - pre_tok, \
        eng1.prefill_dispatches - pre_disp


def test_replicated_prefix_admits_with_zero_prefill_and_parity(gt):
    """THE acceptance flow: m1 pulls the vetoed holder's prefix pages
    once, all three siblings admit against the replica with zero prefill
    dispatches for the replicated blocks, and outputs are token-identical
    to serving the same requests by prefill-from-scratch."""
    rep_nodes, rep_sink, rep_tok, rep_disp = _run_siblings(gt, True)
    # one fetch, the other siblings piggybacked on it
    m0, m1 = rep_nodes
    assert m1.metrics["replicate_routes"] == 3
    assert m1.metrics["kv_fetches"] == 1
    assert m1.metrics["kv_fetch_piggybacks"] == 2
    assert m1.metrics["kv_imported_pages"] == 3
    assert m1.metrics["kv_fallbacks"] == 0
    assert m0.metrics["kv_exports"] == 1
    assert m0.metrics["kv_export_refused"] == 0
    # zero prefill dispatches for the replicated blocks: m1 prefilled
    # ONLY the 8-token divergence tails (one batched admission round)
    assert rep_tok == 3 * 8
    assert rep_disp == 1
    # the holder never re-prefilled either (it only exported)
    assert m0.real_engine.kv_exported_pages == 3
    # refcount parity after the burst: nothing leaked on either node
    m0.real_engine.allocator.check()
    m1.real_engine.allocator.check()

    lb_nodes, lb_sink, lb_tok, lb_disp = _run_siblings(gt, False)
    # token-identical outputs vs prefill-from-scratch...
    assert rep_sink.got == lb_sink.got
    # ...which re-prefilled the whole shared prefix on m1
    assert lb_nodes[1].metrics["kv_fetches"] == 0
    assert lb_tok == 3 * (96 + 8)
    assert lb_disp > rep_disp


def test_refusal_falls_back_to_prefill(gt):
    """Holder evicted the entry between the sketch broadcast and the
    kv_fetch: the fetch is refused and the importer serves by plain
    prefill — replication is never a correctness dependency."""
    net, nodes, sink = _build(gt, True)
    _seed_and_pressure(net, nodes)
    m0 = nodes[0].real_engine
    while m0.prefix_cache.pop_lru():      # evict everything post-broadcast
        pass
    eng1 = nodes[1].real_engine
    pre = eng1.prefill_tokens
    net.call_after(0.01, nodes[1]._process, net,
                   direct_payload("sib0", SHARED + [10] * 8, 4))
    net.run_until(net.t + 60)
    assert len(sink.got) == 2
    assert nodes[1].metrics["kv_refusals"] == 1
    assert nodes[1].metrics["kv_fallbacks"] == 1
    assert nodes[0].metrics["kv_export_refused"] == 1
    assert eng1.prefill_tokens - pre == 96 + 8       # full from-scratch
    m0.allocator.check()
    eng1.allocator.check()


def test_garbled_pages_fall_back_without_crashing(gt):
    """A byzantine/version-skewed holder's un-decodable kv_pages payload
    must degrade to plain prefill — never escape into the node's message
    loop."""
    net, nodes, sink = _build(gt, True)
    _seed_and_pressure(net, nodes)
    net.call_after(0.01, nodes[1]._process, net,
                   direct_payload("sib0", SHARED + [10] * 8, 4))
    # corrupt the holder's reply in flight: garble every kv_pages chunk
    real_send = net.send

    def tamper(src, dst, msg, size_bytes=1024):
        if isinstance(msg, dict) and msg.get("type") == "kv_pages":
            msg = dict(msg, data=b"\xde\xad" * 8)
        real_send(src, dst, msg, size_bytes)
    net.send = tamper
    net.run_until(net.t + 60)
    assert "sib0" in sink.got
    assert nodes[1].metrics["kv_import_failures"] == 1
    assert nodes[1].metrics["kv_fallbacks"] == 1
    nodes[1].real_engine.allocator.check()


def test_fetch_timeout_falls_back(gt):
    """A dead holder never answers: the fetch times out and the request
    is still served by plain prefill."""
    net, nodes, sink = _build(gt, True)
    _seed_and_pressure(net, nodes)
    net.remove_node("m0")                 # holder churns out
    net.call_after(0.01, nodes[1]._process, net,
                   direct_payload("sib0", SHARED + [10] * 8, 4))
    net.run_until(net.t + 120)
    assert "sib0" in sink.got
    assert nodes[1].metrics["kv_timeouts"] == 1
    assert nodes[1].metrics["kv_fallbacks"] == 1
    nodes[1].real_engine.allocator.check()


def test_chunked_pages_reassemble(gt):
    """A chunk budget smaller than the payload splits kv_pages into many
    messages; the importer reassembles them in order."""
    cfg, model, params = gt
    net = SimNet(seed=5)
    fwd = ForwardingConfig(replicate=True)
    nodes = [ModelNode(f"m{i}", use_crypto=False, fwd_cfg=fwd,
                       kv_chunk_bytes=1024,
                       real_engine=RealEngine(cfg, model, params,
                                              max_len=128))
             for i in range(2)]
    for n in nodes:
        net.add_node(n.node_id, n)
    for n in nodes:
        n.join_group(["m0", "m1"])
    sink = ResponseSink()
    net.add_node("sink", sink)
    _seed_and_pressure(net, nodes)
    net.call_after(0.01, nodes[1]._process, net,
                   direct_payload("sib0", SHARED + [10] * 8, 4))
    net.run_until(net.t + 60)
    assert "sib0" in sink.got
    assert nodes[1].metrics["kv_imported_pages"] == 3
    # the payload really was chunked (3 fp16 pages >> 1 KiB)
    assert nodes[1].metrics["kv_wire_bytes"] > 1024
