"""Property-based page-allocator invariants (hypothesis-optional) plus
deterministic cross-node page-migration edge cases.

The model under test is the host-side refcounted allocator behind the
paged KV pool (serving/page_pool.py).  Invariants:

  * alloc never hands out the scratch page or a page somebody holds
  * refcounts track an independent python model exactly
  * a page returns to the free list precisely when its last reference
    drops — aliased pages are never reclaimed while referenced
  * double free / incref-after-free are hard errors
  * used_count + free_count == num_pages - 1 at all times

The migration edge cases (importer out of pages mid-import, holder
evicted the entry before the fetch landed, refcount parity after
replicate + release) are deterministic and run without hypothesis.
"""
import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.serving.page_pool import (NULL_PAGE, OutOfPages, PageAllocator,
                                     PagedHandle)

if HAVE_HYPOTHESIS:
    # an op is ("alloc", n) | ("incref", i) | ("decref", i) where i picks
    # a live page by index modulo the live set
    OPS = st.lists(
        st.one_of(
            st.tuples(st.just("alloc"), st.integers(0, 4)),
            st.tuples(st.just("incref"), st.integers(0, 63)),
            st.tuples(st.just("decref"), st.integers(0, 63)),
        ),
        min_size=1, max_size=200)

    @given(num_pages=st.integers(2, 40), ops=OPS)
    @settings(max_examples=60, deadline=None)
    def test_refcount_model_agreement(num_pages, ops):
        a = PageAllocator(num_pages)
        model = {}                           # page -> refcount
        for op, arg in ops:
            if op == "alloc":
                if arg <= a.free_count:
                    got = a.alloc(arg)
                    assert NULL_PAGE not in got
                    assert not (set(got) & set(model)), "live page re-handed"
                    for p in got:
                        model[p] = 1
                else:
                    with pytest.raises(OutOfPages):
                        a.alloc(arg)
            elif model:
                pages = sorted(model)
                p = pages[arg % len(pages)]
                if op == "incref":
                    a.incref([p])
                    model[p] += 1
                else:
                    a.decref([p])
                    model[p] -= 1
                    if not model[p]:
                        del model[p]
            # allocator agrees with the model after every op
            assert a.used_count == len(model)
            assert a.free_count == (num_pages - 1) - len(model)
            for p, rc in model.items():
                assert a.refcount(p) == rc
            a.check()

    @given(ops=OPS)
    @settings(max_examples=40, deadline=None)
    def test_freed_pages_are_reusable_and_only_when_unreferenced(ops):
        """An aliased page (refcount >= 2) must survive any single decref
        and must not reappear from alloc until fully released."""
        a = PageAllocator(16)
        held = []                            # pages with an extra alias
        for op, arg in ops:
            if op == "alloc" and a.free_count:
                (p,) = a.alloc(1)
                a.incref([p])                # alias it immediately
                held.append(p)
            elif op == "decref" and held:
                p = held[arg % len(held)]
                a.decref([p])                # drop ONE of two refs
                assert a.refcount(p) == 1    # alias keeps it live
                if a.free_count:
                    fresh = a.alloc(1)
                    assert p not in fresh    # never re-handed while held
                    a.decref(fresh)
                a.decref([p])                # now truly free
                held.remove(p)
            a.check()

    @given(st.lists(st.integers(1, 400), min_size=1, max_size=30))
    @settings(max_examples=40, deadline=None)
    def test_handles_are_pure_indices(lengths):
        """PagedHandle equality/identity never touches device memory —
        the prefix cache can hold thousands of them for free."""
        hs = [PagedHandle(tuple(range(1, 1 + n % 7)), n) for n in lengths]
        for h, n in zip(hs, lengths):
            assert h.length == n
            assert all(p != NULL_PAGE for p in h.pages)


# ==========================================================================
# Cross-node page-migration edge cases (deterministic)
# ==========================================================================

@pytest.fixture(scope="module")
def gt():
    from repro.configs import base
    from repro.models.lm import build_model
    cfg = base.get_config("gentorrent-llama3-8b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


SHARED = [7] * 96                       # three full blocks


def _seeded_export(gt, depth=3, mode="raw"):
    from repro.serving.engine import RealEngine, Request
    from repro.serving.prefix_cache import _chain_hashes
    cfg, model, params = gt
    src = RealEngine(cfg, model, params, max_len=128)
    src.generate(Request(0, SHARED + [1] * 8, max_new=2))
    _, entry = src.prefix_cache.peek(SHARED)
    return (src, src.export_pages(entry.handle, depth=depth, mode=mode),
            _chain_hashes(SHARED)[:depth])


def test_importer_out_of_pages_releases_and_falls_back(gt):
    """An importer whose arena cannot host the pages (free pages pinned
    by live requests, nothing evictable) must raise OutOfPages with every
    allocated page released — and still serve the request by prefill."""
    from repro.serving.engine import RealEngine, Request
    cfg, model, params = gt
    _, buf, chains = _seeded_export(gt)
    dst = RealEngine(cfg, model, params, max_len=128,
                     num_pages=1 + 4)           # 4 usable pages
    pinned = dst.alloc_pages(2)                 # live requests, not cache:
    free0 = dst.allocator.free_count            # pop_lru can't reclaim them
    with pytest.raises(OutOfPages):
        dst.import_pages(buf, chains)           # needs 3, only 2 free
    # nothing leaked, nothing registered
    assert dst.allocator.free_count == free0
    assert dst.prefix_cache.peek(SHARED) == (0, None)
    dst.allocator.check()
    # fallback: plain prefill of a tail-block request still works
    out = dst.generate(Request(1, SHARED[:32] + [9] * 8, max_new=2))
    assert out.output and out.cached_tokens == 0
    dst.release_pages(pinned)
    dst.allocator.check()


def test_import_failure_mid_scatter_releases_pages(gt, monkeypatch):
    """A failure AFTER allocation (decode error mid-import) must hand the
    fresh pages back before propagating."""
    from repro.serving import engine as eng_mod
    from repro.serving.engine import RealEngine
    cfg, model, params = gt
    _, buf, chains = _seeded_export(gt)
    dst = RealEngine(cfg, model, params, max_len=128)
    free0 = dst.allocator.free_count

    def boom(rec, dtype=None):
        raise RuntimeError("corrupt wire payload")
    monkeypatch.setattr(eng_mod, "decompress_kv_blocks", boom)
    with pytest.raises(RuntimeError):
        dst.import_pages(buf, chains)
    assert dst.allocator.free_count == free0
    assert dst.prefix_cache.peek(SHARED) == (0, None)
    dst.allocator.check()


def test_holder_eviction_refuses_fetch(gt):
    """The holder evicted the entry between the sketch broadcast that
    attracted the fetch and the kv_fetch itself: it must refuse (ok=False)
    rather than export stale or foreign pages."""
    from repro.serving.prefix_cache import _chain_hashes
    src, _, _ = _seeded_export(gt)

    class _Capture:
        def __init__(self):
            self.sent = []

        def send(self, src_id, dst, msg, size_bytes=0):
            self.sent.append(msg)

    from repro.overlay.model_node import ModelNode
    holder = ModelNode("m0", use_crypto=False, real_engine=src)
    net = _Capture()
    chains = _chain_hashes(SHARED)
    while src.prefix_cache.pop_lru():           # the eviction race
        pass
    holder._handle_kv_fetch(net, {"type": "kv_fetch", "from": "m1",
                                  "fetch_id": 1, "chains": chains,
                                  "depth": 3})
    assert len(net.sent) == 1 and net.sent[0]["ok"] is False
    assert holder.metrics["kv_export_refused"] == 1
    src.allocator.check()


def test_refcount_parity_after_replicate_and_release(gt):
    """Both allocators stay consistent through export -> import ->
    aliased admission -> completion -> full release: the holder never
    moves a refcount, the importer ends exactly where it started."""
    from repro.serving.engine import RealEngine, Request
    from repro.serving.scheduler import Scheduler
    cfg, model, params = gt
    src, buf, chains = _seeded_export(gt)
    src_refs = [src.allocator.refcount(p) for p in range(src.num_pages)]
    dst = RealEngine(cfg, model, params, max_len=128)
    handle = dst.import_pages(buf, chains)
    assert [src.allocator.refcount(p) for p in range(src.num_pages)] \
        == src_refs                              # export moved nothing
    # an admitted sibling aliases the replica (refcount 2) and returns it
    s = Scheduler(dst, max_active=2)
    s.submit(Request(1, SHARED + [9] * 8, max_new=4))
    s.step()
    assert all(dst.allocator.refcount(p) == 2 for p in handle.pages)
    s.run()
    # completion re-registered the deeper prefix over the same physical
    # pages; dropping every cache entry frees the whole arena
    while dst.prefix_cache.pop_lru():
        pass
    assert dst.allocator.free_count == dst.num_pages - 1
    dst.allocator.check()
    src.allocator.check()


def test_int8_wire_mode_imports_and_serves(gt):
    """The quantized wire mode lands near-exact K/V: admission over an
    int8 replica still serves (bounded error, never a crash path)."""
    from repro.serving.engine import RealEngine, Request
    cfg, model, params = gt
    src, buf, chains = _seeded_export(gt, mode="int8")
    dst = RealEngine(cfg, model, params, max_len=128)
    handle = dst.import_pages(buf, chains)
    a = np.asarray(src.arena[0]["k"][:, list(
        src.prefix_cache.peek(SHARED)[1].handle.pages[:3])])
    b = np.asarray(dst.arena[0]["k"][:, list(handle.pages)])
    assert np.max(np.abs(a - b)) <= np.max(np.abs(a)) / 127.0 + 1e-7
    out = dst.generate(Request(1, SHARED + [9] * 8, max_new=2))
    assert out.cached_tokens == 96 and out.output
    dst.allocator.check()

