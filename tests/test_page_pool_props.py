"""Property-based page-allocator invariants (hypothesis).

The model under test is the host-side refcounted allocator behind the
paged KV pool (serving/page_pool.py).  Invariants:

  * alloc never hands out the scratch page or a page somebody holds
  * refcounts track an independent python model exactly
  * a page returns to the free list precisely when its last reference
    drops — aliased pages are never reclaimed while referenced
  * double free / incref-after-free are hard errors
  * used_count + free_count == num_pages - 1 at all times
"""
import pytest
from hypothesis import given, settings, strategies as st

from repro.serving.page_pool import (NULL_PAGE, OutOfPages, PageAllocator,
                                     PagedHandle)

# an op is ("alloc", n) | ("incref", i) | ("decref", i) where i picks a
# live page by index modulo the live set
OPS = st.lists(
    st.one_of(
        st.tuples(st.just("alloc"), st.integers(0, 4)),
        st.tuples(st.just("incref"), st.integers(0, 63)),
        st.tuples(st.just("decref"), st.integers(0, 63)),
    ),
    min_size=1, max_size=200)


@given(num_pages=st.integers(2, 40), ops=OPS)
@settings(max_examples=60, deadline=None)
def test_refcount_model_agreement(num_pages, ops):
    a = PageAllocator(num_pages)
    model = {}                               # page -> refcount
    for op, arg in ops:
        if op == "alloc":
            if arg <= a.free_count:
                got = a.alloc(arg)
                assert NULL_PAGE not in got
                assert not (set(got) & set(model)), "live page re-handed"
                for p in got:
                    model[p] = 1
            else:
                with pytest.raises(OutOfPages):
                    a.alloc(arg)
        elif model:
            pages = sorted(model)
            p = pages[arg % len(pages)]
            if op == "incref":
                a.incref([p])
                model[p] += 1
            else:
                a.decref([p])
                model[p] -= 1
                if not model[p]:
                    del model[p]
        # allocator agrees with the model after every op
        assert a.used_count == len(model)
        assert a.free_count == (num_pages - 1) - len(model)
        for p, rc in model.items():
            assert a.refcount(p) == rc
        a.check()


@given(ops=OPS)
@settings(max_examples=40, deadline=None)
def test_freed_pages_are_reusable_and_only_when_unreferenced(ops):
    """An aliased page (refcount >= 2) must survive any single decref and
    must not reappear from alloc until fully released."""
    a = PageAllocator(16)
    held = []                                # pages with an extra alias
    for op, arg in ops:
        if op == "alloc" and a.free_count:
            (p,) = a.alloc(1)
            a.incref([p])                    # alias it immediately
            held.append(p)
        elif op == "decref" and held:
            p = held[arg % len(held)]
            a.decref([p])                    # drop ONE of two refs
            assert a.refcount(p) == 1        # alias keeps it live
            if a.free_count:
                fresh = a.alloc(1)
                assert p not in fresh        # never re-handed while held
                a.decref(fresh)
            a.decref([p])                    # now truly free
            held.remove(p)
        a.check()


@given(st.lists(st.integers(1, 400), min_size=1, max_size=30))
@settings(max_examples=40, deadline=None)
def test_handles_are_pure_indices(lengths):
    """PagedHandle equality/identity never touches device memory — the
    prefix cache can hold thousands of them for free."""
    hs = [PagedHandle(tuple(range(1, 1 + n % 7)), n) for n in lengths]
    for h, n in zip(hs, lengths):
        assert h.length == n
        assert all(p != NULL_PAGE for p in h.pages)
