"""Collection guard: property-based test modules need ``hypothesis``
(requirements-dev.txt).  When it isn't installed, skip those modules
instead of failing the whole collection, so the deterministic tier-1
suite still runs on a bare interpreter.  Modules that declare
``hypothesis-optional`` guard the import themselves and keep their
deterministic tests collectable either way.  CI installs the dev extras
and runs everything.
"""
import importlib.util
import pathlib

collect_ignore = []
if importlib.util.find_spec("hypothesis") is None:
    _here = pathlib.Path(__file__).parent
    collect_ignore = sorted(
        f.name for f in _here.glob("test_*.py")
        if ("from hypothesis" in f.read_text() or
            "import hypothesis" in f.read_text())
        and "hypothesis-optional" not in f.read_text())
