"""CI bench regression gate: tolerance, missing/mismatch, unknown-file
and --update/--summary paths of scripts/check_bench.py."""
import importlib.util
import json
from pathlib import Path

import pytest

_SPEC = importlib.util.spec_from_file_location(
    "check_bench",
    Path(__file__).resolve().parent.parent / "scripts" / "check_bench.py")
cb = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(cb)


@pytest.fixture()
def gate(tmp_path, monkeypatch):
    """Small synthetic gate: one artifact, two gated keys, one extra."""
    results = tmp_path / "results"
    baseline = results / "baseline"
    results.mkdir()
    baseline.mkdir()
    monkeypatch.setattr(cb, "GATED", {"fake_quick.json": ["a.b", "zero"]})
    monkeypatch.setattr(cb, "SUMMARY_EXTRA",
                        {"fake_quick.json": ["wall_s"]})
    monkeypatch.delenv("GITHUB_STEP_SUMMARY", raising=False)

    def write(dir_, payload):
        (dir_ / "fake_quick.json").write_text(json.dumps(payload))

    def run(*extra):
        return cb.main(["--results", str(results),
                        "--baseline", str(baseline), *extra])

    return results, baseline, write, run


def _payload(v=10.0, zero=0.0, wall=1.5):
    return {"a": {"b": v}, "zero": zero, "wall_s": wall}


def test_pass_within_tolerance(gate):
    results, baseline, write, run = gate
    write(baseline, _payload(10.0))
    write(results, _payload(12.0))          # +20% < default ±30%
    assert run() == 0


def test_fail_beyond_tolerance_both_directions(gate, capsys):
    results, baseline, write, run = gate
    write(baseline, _payload(10.0))
    write(results, _payload(15.0))          # +50%
    assert run() == 1
    assert "a.b" in capsys.readouterr().err
    write(results, _payload(4.0))           # -60%: improvements fail too
    assert run() == 1
    # tightening/loosening the tolerance flips the verdict
    write(results, _payload(12.0))
    assert run("--tol", "0.1") == 1
    assert run("--tol", "0.3") == 0


def test_zero_baseline_is_exact_invariant(gate):
    results, baseline, write, run = gate
    write(baseline, _payload(zero=0.0))
    write(results, _payload(zero=1.0))      # any drift off zero fails
    assert run() == 1
    write(results, _payload(zero=0.0))
    assert run() == 0


def test_missing_baseline_fails_with_update_hint(gate, capsys):
    results, baseline, write, run = gate
    write(results, _payload())
    assert run() == 1
    assert "--update" in capsys.readouterr().err


def test_missing_artifact_fails(gate, capsys):
    results, baseline, write, run = gate
    write(baseline, _payload())
    assert run() == 1
    assert "artifact missing" in capsys.readouterr().err


def test_key_missing_from_artifact_or_baseline(gate, capsys):
    results, baseline, write, run = gate
    write(baseline, _payload())
    (results / "fake_quick.json").write_text(json.dumps({"zero": 0.0}))
    assert run() == 1
    assert "missing from artifact" in capsys.readouterr().err
    (baseline / "fake_quick.json").write_text(json.dumps({"zero": 0.0}))
    write(results, _payload())
    assert run() == 1
    assert "not in baseline" in capsys.readouterr().err


def test_unknown_quick_artifact_is_hard_failure(gate, capsys):
    """A quick-bench JSON with no GATED registration must fail the gate
    (it would otherwise regress silently), pointing at GATED + --update."""
    results, baseline, write, run = gate
    write(baseline, _payload())
    write(results, _payload())
    (results / "rogue_quick.json").write_text("{}")
    assert run() == 1
    err = capsys.readouterr().err
    assert "rogue_quick.json" in err and "GATED" in err
    # non-quick JSONs (full-mode artifacts) are not the gate's business
    (results / "rogue_quick.json").unlink()
    (results / "fullmode.json").write_text("{}")
    assert run() == 0


def test_update_copies_all_quick_artifacts(gate):
    results, baseline, write, run = gate
    write(results, _payload())
    (results / "rogue_quick.json").write_text("{}")
    assert run("--update") == 0
    assert (baseline / "fake_quick.json").exists()
    assert (baseline / "rogue_quick.json").exists()   # committed alongside
    (results / "rogue_quick.json").unlink()
    (baseline / "rogue_quick.json").unlink()
    assert run() == 0                       # refreshed baseline now gates


def test_summary_written_to_step_summary_file(gate, tmp_path, monkeypatch):
    results, baseline, write, run = gate
    write(baseline, _payload(10.0))
    write(results, _payload(20.0, wall=9.9))     # gated fail + extra row
    dest = tmp_path / "step_summary.md"
    monkeypatch.setenv("GITHUB_STEP_SUMMARY", str(dest))
    assert run("--summary") == 1
    text = dest.read_text()
    assert "Quick-bench summary" in text
    assert "a.b" in text and "+100.0%" in text and "❌" in text
    assert "wall_s" in text                 # ungated highlight row rides
    # stdout fallback when the env var is unset
    monkeypatch.delenv("GITHUB_STEP_SUMMARY")
    write(results, _payload(10.0))
    assert run("--summary") == 0
